//! Project folders and rule-based templates.
//!
//! The paper organizes every tuning activity as a *project folder* built
//! from templates ("Catla uses rule-based templates to organize necessary
//! information of tuning MapReduce jobs"). Three kinds:
//!
//! * **task** — one job: `HadoopEnv.txt` + `job.properties`
//! * **project** — a job group: adds `jobs.list`
//! * **tuning** — an optimization run: adds `params.spec` + `tuning.properties`
//!
//! After a run the folder gains `downloaded_results/` (history.json,
//! container logs, outputs) and `history/` (CSV summaries) — exactly the
//! Step-5 layout of the paper's §II.B.2 walkthrough.

use std::path::{Path, PathBuf};

use crate::config::env::HadoopEnv;
use crate::config::params::HadoopConfig;
use crate::config::scope::ScopedSpec;
use crate::config::spec::TuningSpec;
use crate::util::durable::atomic_write;
use crate::workloads::{self, WorkloadSpec};

/// Key=value properties file (job.properties / tuning.properties).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Properties {
    pub entries: Vec<(String, String)>,
}

impl Properties {
    pub fn parse(text: &str) -> Result<Properties, String> {
        let mut entries = Vec::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("properties line {}: expected key=value", no + 1))?;
            entries.push((k.trim().to_string(), v.trim().to_string()));
        }
        Ok(Properties { entries })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn set(&mut self, key: &str, value: &str) {
        match self.entries.iter_mut().find(|(k, _)| k == key) {
            Some((_, v)) => *v = value.to_string(),
            None => self.entries.push((key.to_string(), value.to_string())),
        }
    }
}

/// Prints exactly what [`Properties::parse`] accepts.
impl std::fmt::Display for Properties {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (k, v) in &self.entries {
            writeln!(f, "{k}={v}")?;
        }
        Ok(())
    }
}

/// Kind of project folder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectKind {
    Task,
    Project,
    Tuning,
}

/// A loaded project folder.
#[derive(Clone, Debug)]
pub struct Project {
    pub dir: PathBuf,
    pub kind: ProjectKind,
    pub env: HadoopEnv,
    pub job: Properties,
    /// `tuning.properties`, for tuning projects.
    pub tuning: Option<Properties>,
    /// `params.spec` as parsed: the shared (global) spec plus any
    /// `workload <name> { ... }` blocks. Multi-job/workflow tuning
    /// merges the blocks of the workloads it runs.
    pub scoped: Option<ScopedSpec>,
    /// The *effective* flat spec for this project's own job: the global
    /// spec with the job's workload block applied over it (identical to
    /// the file for flat specs). Single-job `tuning`/`resume` runs use
    /// this.
    pub spec: Option<TuningSpec>,
    /// `jobs.list` lines, for project folders.
    pub jobs: Vec<String>,
}

impl Project {
    /// Load and validate a project folder.
    pub fn load(dir: &Path) -> Result<Project, String> {
        if !dir.is_dir() {
            return Err(format!("project folder {} does not exist", dir.display()));
        }
        let env = HadoopEnv::load(&dir.join("HadoopEnv.txt"))?;
        let job = Properties::parse(
            &std::fs::read_to_string(dir.join("job.properties"))
                .map_err(|e| format!("job.properties: {e}"))?,
        )?;
        let tuning_path = dir.join("tuning.properties");
        let spec_path = dir.join("params.spec");
        let jobs_path = dir.join("jobs.list");
        let kind = if tuning_path.is_file() {
            ProjectKind::Tuning
        } else if jobs_path.is_file() {
            ProjectKind::Project
        } else {
            ProjectKind::Task
        };
        let tuning = if tuning_path.is_file() {
            Some(Properties::parse(
                &std::fs::read_to_string(&tuning_path).map_err(|e| e.to_string())?,
            )?)
        } else {
            None
        };
        let scoped = if spec_path.is_file() {
            Some(ScopedSpec::load(&spec_path)?)
        } else {
            None
        };
        let spec = scoped.as_ref().map(|s| match job.get("workload") {
            Some(w) => s.scope(w).clone(),
            None => s.global.clone(),
        });
        if kind == ProjectKind::Tuning && spec.is_none() {
            return Err("tuning project missing params.spec".into());
        }
        let jobs = if jobs_path.is_file() {
            std::fs::read_to_string(&jobs_path)
                .map_err(|e| e.to_string())?
                .lines()
                .map(|l| l.trim().to_string())
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .collect()
        } else {
            Vec::new()
        };
        Ok(Project {
            dir: dir.to_path_buf(),
            kind,
            env,
            job,
            tuning,
            scoped,
            spec,
            jobs,
        })
    }

    /// Resolve the workload this project's job runs.
    pub fn workload(&self) -> Result<WorkloadSpec, String> {
        let name = self
            .job
            .get("workload")
            .ok_or("job.properties missing `workload`")?;
        let input_mb: f64 = self
            .job
            .get("input.mb")
            .unwrap_or("1024")
            .parse()
            .map_err(|_| "bad input.mb")?;
        workloads::by_name(name, input_mb)
            .ok_or_else(|| format!("unknown workload {name:?} (known: {:?})", workloads::BUILTIN_NAMES))
    }

    /// Base Hadoop configuration: defaults + `conf.<param>=value`
    /// overrides. Laid out on the spec's registry when the project has a
    /// `params.spec` (so overrides can target spec-declared parameters);
    /// categorical params accept their label as the value.
    pub fn base_config(&self) -> Result<HadoopConfig, String> {
        let registry = match &self.spec {
            Some(s) => s.registry.clone(),
            None => crate::config::space::ParamRegistry::builtin(),
        };
        let mut cfg = HadoopConfig::for_registry(registry);
        for (k, v) in &self.job.entries {
            if let Some(param) = k.strip_prefix("conf.") {
                // ParamDef::parse_value is the inverse of format_value,
                // so every value form the system prints can be fed back
                // in: categorical labels, true/false for bools, numbers
                let (index, val) = {
                    let (i, d) = cfg.registry().resolve(param)?;
                    let val = d
                        .parse_value(v)
                        .map_err(|e| format!("bad value for {k}: {e}"))?;
                    (i, val)
                };
                cfg.set(index, val);
            }
        }
        Ok(cfg)
    }

    pub fn results_dir(&self) -> PathBuf {
        self.dir.join("downloaded_results")
    }

    pub fn history_dir(&self) -> PathBuf {
        self.dir.join("history")
    }
}

/// Materialize a template folder (the paper's "task-based template").
pub fn create_template(
    dir: &Path,
    kind: ProjectKind,
    workload: &str,
    input_mb: f64,
) -> Result<(), String> {
    if workloads::by_name(workload, input_mb).is_none() {
        return Err(format!("unknown workload {workload:?}"));
    }
    std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    HadoopEnv::default()
        .save(&dir.join("HadoopEnv.txt"))
        .map_err(|e| e.to_string())?;
    let mut job = Properties::default();
    job.set("name", &format!("{workload}-job"));
    job.set("workload", workload);
    job.set("input.mb", &format!("{input_mb}"));
    job.set("jar", &format!("{workload}.jar")); // cosmetic against a sim cluster
    atomic_write(&dir.join("job.properties"), job.to_string().as_bytes())
        .map_err(|e| e.to_string())?;
    match kind {
        ProjectKind::Task => {}
        ProjectKind::Project => {
            atomic_write(
                &dir.join("jobs.list"),
                format!("# one job per line: <name> <workload> <input_mb> [conf.param=value ...]\n\
                         {workload}-small {workload} {}\n{workload}-large {workload} {}\n",
                        input_mb / 4.0, input_mb).as_bytes(),
            )
            .map_err(|e| e.to_string())?;
        }
        ProjectKind::Tuning => {
            atomic_write(&dir.join("params.spec"), TuningSpec::fig3().to_string().as_bytes())
                .map_err(|e| e.to_string())?;
            let mut t = Properties::default();
            t.set("optimizer", "bobyqa");
            t.set("budget", "60");
            t.set("repeats", "1");
            t.set("seed", "7");
            atomic_write(&dir.join("tuning.properties"), t.to_string().as_bytes())
                .map_err(|e| e.to_string())?;
        }
    }
    Ok(())
}

/// Materialize a multi-workload tuning template: a `jobs.list` with one
/// job per workload and a scoped `params.spec` assembled from the
/// suites' attached tuning blocks (shuffle-heavy terasort gets codec +
/// parallelcopies, CPU-bound wordcount memory + slowstart, …) — the
/// starting point for `tuning-group` / `workflow --tune` over a merged
/// space. CLI: `catla template --kind tuning --workloads a,b,...`.
pub fn create_scoped_template(
    dir: &Path,
    workload_names: &[&str],
    input_mb: f64,
) -> Result<(), String> {
    if workload_names.is_empty() {
        return Err("scoped template needs at least one workload".into());
    }
    let workloads: Vec<WorkloadSpec> = workload_names
        .iter()
        .map(|w| {
            workloads::by_name(w, input_mb).ok_or_else(|| format!("unknown workload {w:?}"))
        })
        .collect::<Result<_, _>>()?;
    create_template(dir, ProjectKind::Tuning, &workloads[0].name, input_mb)?;
    let refs: Vec<&WorkloadSpec> = workloads.iter().collect();
    atomic_write(
        &dir.join("params.spec"),
        workloads::suggested_scoped_spec(&refs).as_bytes(),
    )
    .map_err(|e| e.to_string())?;
    let jobs: String = workloads
        .iter()
        .map(|w| format!("{0}-job {0} {input_mb}\n", w.name))
        .collect();
    atomic_write(
        &dir.join("jobs.list"),
        format!("# one job per line: <name> <workload> <input_mb> [conf.param=value ...]\n{jobs}")
            .as_bytes(),
    )
    .map_err(|e| e.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-test-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn task_template_roundtrip() {
        let dir = tmp("task");
        create_template(&dir, ProjectKind::Task, "wordcount", 2048.0).unwrap();
        let p = Project::load(&dir).unwrap();
        assert_eq!(p.kind, ProjectKind::Task);
        assert_eq!(p.workload().unwrap().name, "wordcount");
        assert_eq!(p.workload().unwrap().input_mb, 2048.0);
        p.base_config().unwrap().validate().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tuning_template_has_spec() {
        let dir = tmp("tuning");
        create_template(&dir, ProjectKind::Tuning, "terasort", 4096.0).unwrap();
        let p = Project::load(&dir).unwrap();
        assert_eq!(p.kind, ProjectKind::Tuning);
        assert!(p.spec.is_some());
        assert_eq!(p.tuning.as_ref().unwrap().get("optimizer"), Some("bobyqa"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn project_template_lists_jobs() {
        let dir = tmp("project");
        create_template(&dir, ProjectKind::Project, "grep", 1024.0).unwrap();
        let p = Project::load(&dir).unwrap();
        assert_eq!(p.kind, ProjectKind::Project);
        assert_eq!(p.jobs.len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn conf_overrides_apply() {
        let dir = tmp("conf");
        create_template(&dir, ProjectKind::Task, "wordcount", 512.0).unwrap();
        let mut text = std::fs::read_to_string(dir.join("job.properties")).unwrap();
        text.push_str("conf.mapreduce.job.reduces=12\n");
        std::fs::write(dir.join("job.properties"), text).unwrap();
        let p = Project::load(&dir).unwrap();
        assert_eq!(
            p.base_config().unwrap().get(crate::config::params::P_REDUCES),
            12.0
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn conf_overrides_reach_spec_declared_params() {
        let dir = tmp("conf-extra");
        create_template(&dir, ProjectKind::Tuning, "wordcount", 512.0).unwrap();
        std::fs::write(
            dir.join("params.spec"),
            "param mapreduce.map.output.compress.codec cat none,snappy,lz4\n\
             param x.shuffle.buffer.kb int 32 4096\n",
        )
        .unwrap();
        let mut text = std::fs::read_to_string(dir.join("job.properties")).unwrap();
        text.push_str(
            "conf.x.shuffle.buffer.kb=256\nconf.mapreduce.map.output.compress.codec=snappy\n\
             conf.mapreduce.map.output.compress=true\n",
        );
        std::fs::write(dir.join("job.properties"), text).unwrap();
        let p = Project::load(&dir).unwrap();
        let cfg = p.base_config().unwrap();
        assert_eq!(cfg.get_by_name("x.shuffle.buffer.kb").unwrap(), 256.0);
        // the printed form of a bool (-D...compress=true) feeds back in
        assert!(cfg.get_bool(crate::config::params::P_COMPRESS));
        let codec = cfg
            .registry()
            .index_of("mapreduce.map.output.compress.codec")
            .unwrap();
        assert_eq!(cfg.get_category(codec), Some("snappy"));
        cfg.validate().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scoped_template_roundtrips_through_load() {
        let dir = tmp("scoped");
        create_scoped_template(&dir, &["terasort", "wordcount"], 2048.0).unwrap();
        let p = Project::load(&dir).unwrap();
        assert_eq!(p.kind, ProjectKind::Tuning);
        assert_eq!(p.jobs.len(), 2);
        let scoped = p.scoped.as_ref().unwrap();
        assert_eq!(scoped.scopes.len(), 2);
        // the project's own job is the first workload: its effective
        // spec includes the terasort block
        assert_eq!(p.workload().unwrap().name, "terasort");
        let spec = p.spec.as_ref().unwrap();
        assert!(spec
            .ranges
            .iter()
            .any(|r| r.name() == "mapreduce.reduce.shuffle.parallelcopies"));
        assert!(!spec
            .ranges
            .iter()
            .any(|r| r.name() == "mapreduce.job.reduce.slowstart.completedmaps"));
        assert!(create_scoped_template(&tmp("scoped-bad"), &["nope"], 64.0).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn effective_spec_follows_the_projects_workload_block() {
        let dir = tmp("effective");
        create_template(&dir, ProjectKind::Tuning, "wordcount", 512.0).unwrap();
        std::fs::write(
            dir.join("params.spec"),
            "param mapreduce.job.reduces int 2 32\n\
             workload wordcount {\n\
               param mapreduce.map.memory.mb int 512 4096\n\
             }\n\
             workload terasort {\n\
               param mapreduce.map.output.compress bool\n\
             }\n",
        )
        .unwrap();
        let p = Project::load(&dir).unwrap();
        let spec = p.spec.as_ref().unwrap();
        assert_eq!(spec.dims(), 2); // shared reduces + wordcount's memory
        assert!(spec.ranges.iter().any(|r| r.name() == "mapreduce.map.memory.mb"));
        assert!(!spec
            .ranges
            .iter()
            .any(|r| r.name() == "mapreduce.map.output.compress"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unknown_workload_rejected() {
        let dir = tmp("bad");
        assert!(create_template(&dir, ProjectKind::Task, "sleep", 1.0).is_err());
    }

    #[test]
    fn missing_folder_is_error() {
        assert!(Project::load(Path::new("/nonexistent/project")).is_err());
    }

    #[test]
    fn properties_parse_rejects_garbage() {
        assert!(Properties::parse("key-without-value\n").is_err());
    }
}

//! Terminal dashboard — the CatlaUI feature set ("run, monitor and tune a
//! MapReduce without Windows commands") rendered as a static terminal
//! report over a project folder: recent jobs, tuning state, best config,
//! convergence chart.

use std::path::Path;

use crate::catla::history::History;
use crate::catla::project::{Project, ProjectKind};
use crate::catla::visualize;

/// Render the dashboard for a project folder.
pub fn render(dir: &Path) -> Result<String, String> {
    let project = Project::load(dir)?;
    let mut out = String::new();
    out.push_str(&format!(
        "┌─ Catla dashboard ─ {} ({:?} project)\n",
        dir.display(),
        project.kind
    ));
    let wl = project.workload()?;
    out.push_str(&format!(
        "│ workload: {} ({:.1} GiB input)\n",
        wl.name,
        wl.input_mb / 1024.0
    ));
    out.push_str(&format!(
        "│ cluster : {} nodes (sim), seed {}\n",
        project.env.get_u64("sim.nodes", 16),
        project.env.get_u64("sim.seed", 42)
    ));

    let history = History::open(dir).map_err(|e| e.to_string())?;

    // recent jobs
    match history.load_jobs() {
        Ok(jobs) if !jobs.rows.is_empty() => {
            out.push_str(&format!("│\n│ recent jobs ({} total):\n", jobs.rows.len()));
            let id_i = jobs.col_index("job_id").unwrap_or(0);
            let rt_i = jobs.col_index("runtime_s").unwrap_or(2);
            for row in jobs.rows.iter().rev().take(5) {
                out.push_str(&format!("│   {:<28} {:>9}s\n", row[id_i], row[rt_i]));
            }
        }
        _ => out.push_str("│\n│ no completed jobs yet (run `catla task`)\n"),
    }

    // tuning state
    match history.load_tuning_log() {
        Ok(log) if !log.rows.is_empty() => {
            let conv = History::convergence_from_log(&log)?;
            let best = conv.last().map(|(_, b)| *b).unwrap_or(f64::NAN);
            out.push_str(&format!(
                "│\n│ tuning: {} evaluations, best {:.1}s\n│\n",
                log.rows.len(),
                best
            ));
            for line in visualize::line_chart("│ convergence", &conv, 48, 8).lines() {
                out.push_str(&format!("│ {line}\n"));
            }
        }
        _ => {
            if project.kind == ProjectKind::Tuning {
                out.push_str("│\n│ no tuning log yet (run `catla tuning`)\n");
            }
        }
    }
    out.push_str("└─\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::project::{create_template, ProjectKind};
    use crate::catla::task_runner::TaskRunner;
    use crate::hadoop::{ClusterSpec, SimCluster};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-dash-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn renders_empty_project() {
        let dir = tmp("empty");
        create_template(&dir, ProjectKind::Task, "wordcount", 512.0).unwrap();
        let s = render(&dir).unwrap();
        assert!(s.contains("no completed jobs yet"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn renders_jobs_and_tuning() {
        let dir = tmp("full");
        create_template(&dir, ProjectKind::Tuning, "wordcount", 512.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        TaskRunner::new(&mut cluster).run(&project).unwrap();
        std::fs::write(
            dir.join("tuning.properties"),
            "optimizer=bobyqa\nbudget=10\nseed=1\n",
        )
        .unwrap();
        let project = Project::load(&dir).unwrap();
        crate::catla::OptimizerRunner::new(&mut cluster)
            .run(&project)
            .unwrap();
        let s = render(&dir).unwrap();
        assert!(s.contains("recent jobs"));
        assert!(s.contains("tuning: 10 evaluations"));
        assert!(s.contains("convergence"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_project_is_error() {
        assert!(render(Path::new("/nonexistent")).is_err());
    }
}

//! The per-session checkpoint journal: an append-only, CRC-trailered
//! record log (`history/<log>.journal`) the serve daemon writes one
//! record to per completed slice, replacing the old rewrite-the-whole-CSV
//! checkpoint (which was O(n²) bytes over a session's lifetime and could
//! tear on a crash).
//!
//! Recovery is *re-drive*, not state reload: `ServeSession::open` builds
//! a fresh optimizer, replays any CSV prior the original session had,
//! then re-asks the optimizer slice by slice, verifying each re-asked
//! config bit-for-bit against the journal record and telling back the
//! journaled values (exact `f64` bits). Because every optimizer is
//! deterministic given (settings, seed, told values), the re-driven
//! session is in the *identical* internal state the crashed one was —
//! which is what makes the resumed outcome byte-identical to an
//! uninterrupted run, a bar the old `PriorRuns` replay (fresh optimizer
//! told a flat history) could not meet mid-run.
//!
//! Record payloads are single tab-separated lines (framed + CRC'd by
//! [`crate::util::durable::append_framed`]):
//!
//! * `catla-journal v1 <optimizer> <label> <seed> <budget> <repeats>
//!   <chunk> <patience> <tol-bits> <prior> <params>
//!   [racing:eta=E;min=M]` — written once, before the first slice;
//!   `prior` is the number of tuning-log CSV rows the session replayed
//!   at open, `params` the comma-joined spec range names. The trailing
//!   racing field appears only when `racing.enabled=true`, so
//!   racing-off journals are byte-identical to the pre-racing format
//!   (and v1 journals parse as racing-off). [`Journal::check_header`]
//!   refuses to re-drive under different settings (determinism would
//!   silently break).
//! * `slice <s|x> <eval>...` — one per told slice; `s` slices consumed
//!   simulator seeds, `x` (external ask/tell) did not. Each eval is
//!   `<value-bits>[@<fid>]:<cfg-bits,...>` — full-precision hex bits of
//!   the folded value and of each spec-range config value. The `@<fid>`
//!   marker (see [`Fidelity::label`]) appears only on values racing
//!   pruned below full fidelity, so racing-off slices are byte-identical
//!   to the pre-racing format.
//! * `fin` — the run finalized: the final tuning CSV is durably on disk
//!   (it is written *before* `fin`), the summary row may or may not be.
//!   Recovery appends the summary row only if missing, then removes the
//!   journal.

use std::path::{Path, PathBuf};

use crate::catla::optimizer_runner::TuningSettings;
use crate::config::params::HadoopConfig;
use crate::config::spec::TuningSpec;
use crate::optim::racing::RacingSettings;
use crate::optim::result::Fidelity;
use crate::util::durable;

const MAGIC: &str = "catla-journal v1";
pub const FIN: &str = "fin";
pub const JOURNAL_SUFFIX: &str = ".journal";

/// The journal sibling of a tuning log: `tuning_log.csv` →
/// `tuning_log.csv.journal`, inside the same history directory.
pub fn journal_path(hist_dir: &Path, log_name: &str) -> PathBuf {
    hist_dir.join(format!("{log_name}{JOURNAL_SUFFIX}"))
}

/// Everything the header record pins about the run that wrote the
/// journal — the deterministic inputs a re-drive must match exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalHeader {
    pub optimizer: String,
    pub label: String,
    pub seed: u64,
    pub budget: usize,
    pub repeats: usize,
    pub batch_chunk: usize,
    pub early_patience: usize,
    pub early_tol: f64,
    /// Tuning-log CSV rows the session replayed as prior at open time.
    pub prior: usize,
    pub params: Vec<String>,
    /// Racing knobs the run used (default = off, the v1 header form).
    pub racing: RacingSettings,
}

/// One told slice: the values fed to `tell_values` (exact bits) plus the
/// per-spec-range config values of each candidate, for bitwise
/// verification against the re-asked slice.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalSlice {
    /// `true` for external ask/tell slices (no simulator seeds consumed).
    pub external: bool,
    /// `(folded value, fidelity, config value per spec range)` per
    /// candidate; fidelity is `Full` unless racing pruned the candidate.
    pub evals: Vec<(f64, Fidelity, Vec<f64>)>,
}

#[derive(Clone, Debug)]
pub struct Journal {
    pub header: JournalHeader,
    pub slices: Vec<JournalSlice>,
    /// A `fin` record was present: the final tuning CSV is durable.
    pub finalized: bool,
    /// Byte length of the valid prefix (truncate here to repair a tear).
    pub clean_len: u64,
    /// Invalid trailing bytes (a torn crash mid-append); 0 when clean.
    pub torn_bytes: u64,
}

/// Render the one-time header record payload.
pub fn header_payload(
    settings: &TuningSettings,
    label: &str,
    spec: &TuningSpec,
    prior: usize,
) -> String {
    let params: Vec<&str> = spec.ranges.iter().map(|r| r.name()).collect();
    let mut out = format!(
        "{MAGIC}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:016x}\t{}\t{}",
        settings.optimizer,
        label,
        settings.seed,
        settings.budget,
        settings.repeats.max(1),
        settings.batch_chunk,
        settings.early_patience,
        settings.early_tol.to_bits(),
        prior,
        params.join(",")
    );
    // racing-off headers stay byte-identical to the pre-racing format
    if settings.racing.enabled {
        out.push_str(&format!(
            "\tracing:eta={};min={}",
            settings.racing.eta, settings.racing.min_tier_evals
        ));
    }
    out
}

/// Render one slice record payload from the told slice.
pub fn slice_payload(
    external: bool,
    spec: &TuningSpec,
    cfgs: &[HadoopConfig],
    vals: &[f64],
    fids: &[Fidelity],
) -> String {
    debug_assert_eq!(cfgs.len(), vals.len());
    debug_assert_eq!(cfgs.len(), fids.len());
    let mut out = format!("slice\t{}", if external { "x" } else { "s" });
    for ((cfg, v), fid) in cfgs.iter().zip(vals).zip(fids) {
        let bits: Vec<String> = spec
            .ranges
            .iter()
            .map(|r| format!("{:016x}", cfg.get(r.index).to_bits()))
            .collect();
        out.push('\t');
        // full-fidelity evals carry no marker — the pre-racing format
        let marker = if fid.is_full() {
            String::new()
        } else {
            format!("@{}", fid.label())
        };
        out.push_str(&format!("{:016x}{marker}:{}", v.to_bits(), bits.join(",")));
    }
    out
}

fn parse_bits(field: &str, what: &str) -> Result<f64, String> {
    u64::from_str_radix(field, 16)
        .map(f64::from_bits)
        .map_err(|_| format!("bad {what} bits {field:?}"))
}

fn parse_racing(field: &str) -> Result<RacingSettings, String> {
    let body = field
        .strip_prefix("racing:")
        .ok_or_else(|| format!("bad racing field {field:?} in journal header"))?;
    let mut racing = RacingSettings {
        enabled: true,
        ..RacingSettings::default()
    };
    for part in body.split(';') {
        match part.split_once('=') {
            Some(("eta", v)) => {
                racing.eta = v.parse().map_err(|_| format!("bad racing.eta {v:?}"))?;
            }
            Some(("min", v)) => {
                racing.min_tier_evals =
                    v.parse().map_err(|_| format!("bad racing.min_tier_evals {v:?}"))?;
            }
            _ => return Err(format!("bad racing field part {part:?} in journal header")),
        }
    }
    racing.validate()?;
    Ok(racing)
}

fn parse_header(payload: &str) -> Result<JournalHeader, String> {
    let f: Vec<&str> = payload.split('\t').collect();
    // 11 fields = pre-racing (racing off); 12 = racing-on with the
    // trailing racing:eta=E;min=M field
    if !(f.len() == 11 || f.len() == 12) || f[0] != MAGIC {
        return Err(format!("malformed journal header record ({} fields)", f.len()));
    }
    let num = |i: usize, what: &str| -> Result<usize, String> {
        f[i].parse().map_err(|_| format!("bad {what} {:?} in journal header", f[i]))
    };
    Ok(JournalHeader {
        optimizer: f[1].to_string(),
        label: f[2].to_string(),
        seed: f[3].parse().map_err(|_| format!("bad seed {:?} in journal header", f[3]))?,
        budget: num(4, "budget")?,
        repeats: num(5, "repeats")?,
        batch_chunk: num(6, "batch.chunk")?,
        early_patience: num(7, "early.patience")?,
        early_tol: parse_bits(f[8], "early.tol")?,
        prior: num(9, "prior")?,
        params: if f[10].is_empty() {
            Vec::new()
        } else {
            f[10].split(',').map(str::to_string).collect()
        },
        racing: if f.len() == 12 {
            parse_racing(f[11])?
        } else {
            RacingSettings::default()
        },
    })
}

fn parse_slice(payload: &str, dims: usize) -> Result<JournalSlice, String> {
    let mut f = payload.split('\t');
    f.next(); // "slice"
    let external = match f.next() {
        Some("s") => false,
        Some("x") => true,
        other => return Err(format!("bad slice kind {other:?}")),
    };
    let mut evals = Vec::new();
    for e in f {
        let (vfield, cbits) = e
            .split_once(':')
            .ok_or_else(|| format!("malformed slice eval {e:?}"))?;
        // unmarked value = full fidelity (the pre-racing format)
        let (vbits, fid) = match vfield.split_once('@') {
            None => (vfield, Fidelity::Full),
            Some((v, label)) => (v, Fidelity::parse(label)?),
        };
        let value = parse_bits(vbits, "value")?;
        let cfg: Vec<f64> = cbits
            .split(',')
            .map(|b| parse_bits(b, "config"))
            .collect::<Result<_, _>>()?;
        if cfg.len() != dims {
            return Err(format!("slice eval has {} config dims, header declares {dims}", cfg.len()));
        }
        evals.push((value, fid, cfg));
    }
    if evals.is_empty() {
        return Err("slice record with no evaluations".into());
    }
    Ok(JournalSlice { external, evals })
}

impl Journal {
    /// Load and parse a journal file. `Ok(None)` means nothing usable
    /// survived (every record torn — possible only when the crash tore
    /// the very first, header append): the caller discards the file and
    /// proceeds as if no journal existed. Mid-file corruption — a valid
    /// record after an invalid one, a non-header first record, a record
    /// after `fin`, or an unparseable valid-CRC record — is a hard
    /// error: it cannot be produced by a crash of the append-only
    /// writer, so recovery refuses to guess.
    pub fn load(path: &Path) -> Result<Option<Journal>, String> {
        let log = durable::load_records(path)?;
        if log.records.is_empty() {
            return Ok(None);
        }
        let err = |i: usize, e: String| format!("{}: record {}: {e}", path.display(), i + 1);
        let header = parse_header(&log.records[0]).map_err(|e| err(0, e))?;
        let dims = header.params.len();
        let mut slices = Vec::new();
        let mut finalized = false;
        for (i, rec) in log.records.iter().enumerate().skip(1) {
            if finalized {
                return Err(err(i, "record after fin — journal was tampered with".into()));
            }
            if rec == FIN {
                finalized = true;
            } else if rec.starts_with("slice\t") {
                slices.push(parse_slice(rec, dims).map_err(|e| err(i, e))?);
            } else {
                return Err(err(i, format!("unknown record kind {:?}", rec.split('\t').next().unwrap_or(""))));
            }
        }
        Ok(Some(Journal {
            header,
            slices,
            finalized,
            clean_len: log.clean_len,
            torn_bytes: log.torn_bytes,
        }))
    }

    /// Refuse to re-drive under settings that differ from the ones the
    /// journal was written with — the re-asked candidate stream would
    /// diverge and recovery would not be byte-identical.
    pub fn check_header(&self, settings: &TuningSettings, spec: &TuningSpec) -> Result<(), String> {
        let h = &self.header;
        let params: Vec<String> = spec.ranges.iter().map(|r| r.name().to_string()).collect();
        let mismatch: Option<(&str, String, String)> = if h.optimizer != settings.optimizer {
            Some(("optimizer", h.optimizer.clone(), settings.optimizer.clone()))
        } else if h.seed != settings.seed {
            Some(("seed", h.seed.to_string(), settings.seed.to_string()))
        } else if h.budget != settings.budget {
            Some(("budget", h.budget.to_string(), settings.budget.to_string()))
        } else if h.repeats != settings.repeats.max(1) {
            Some(("repeats", h.repeats.to_string(), settings.repeats.max(1).to_string()))
        } else if h.batch_chunk != settings.batch_chunk {
            Some(("batch.chunk", h.batch_chunk.to_string(), settings.batch_chunk.to_string()))
        } else if h.early_patience != settings.early_patience {
            Some(("early.patience", h.early_patience.to_string(), settings.early_patience.to_string()))
        } else if h.early_tol.to_bits() != settings.early_tol.to_bits() {
            Some(("early.tol", h.early_tol.to_string(), settings.early_tol.to_string()))
        } else if h.params != params {
            Some(("params.spec", h.params.join(","), params.join(",")))
        } else if h.racing != settings.racing && (h.racing.enabled || settings.racing.enabled) {
            // eta/min drift on a racing-off run is irrelevant — only an
            // enabled racing layer shapes the candidate/seed stream
            Some((
                "racing",
                format!(
                    "enabled={},eta={},min={}",
                    h.racing.enabled, h.racing.eta, h.racing.min_tier_evals
                ),
                format!(
                    "enabled={},eta={},min={}",
                    settings.racing.enabled, settings.racing.eta, settings.racing.min_tier_evals
                ),
            ))
        } else {
            None
        };
        match mismatch {
            Some((what, logged, now)) => Err(format!(
                "checkpoint journal was written with a different {what} ({logged} vs {now}); \
                 re-driving it under the new settings would not be deterministic — \
                 run `catla fsck --repair` to materialize the checkpoint as a plain \
                 tuning log and retire the journal, or restore the original settings"
            )),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn settings() -> TuningSettings {
        TuningSettings {
            optimizer: "bobyqa".into(),
            budget: 12,
            repeats: 2,
            seed: 7,
            prescreen: false,
            early_patience: 0,
            early_tol: 1e-3,
            batch_chunk: 8,
            cache_entries: None,
            retry_max: 0,
            retry_backoff_ms: 0,
            racing: Default::default(),
        }
    }

    fn spec() -> TuningSpec {
        TuningSpec::fig2()
    }

    fn journal_with(records: &[String], path: &Path) {
        let _ = std::fs::remove_file(path);
        for r in records {
            durable::append_framed(path, r, "x").unwrap();
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-journal-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn header_and_slice_roundtrip_exact_bits() {
        let dir = tmp("roundtrip");
        let path = journal_path(&dir, "tuning_log.csv");
        let sp = spec();
        let st = settings();
        let mut cfg = crate::config::params::HadoopConfig::default();
        for (i, r) in sp.ranges.iter().enumerate() {
            cfg.set(r.index, 2.0 + i as f64 * 0.1);
        }
        let vals = [123.456789012345_f64, 98.765432109876543_f64];
        journal_with(
            &[
                header_payload(&st, "bobyqa", &sp, 3),
                slice_payload(
                    false,
                    &sp,
                    &[cfg.clone(), cfg.clone()],
                    &vals,
                    &[Fidelity::Full, Fidelity::Full],
                ),
                slice_payload(true, &sp, &[cfg.clone()], &vals[..1], &[Fidelity::Full]),
            ],
            &path,
        );
        let j = Journal::load(&path).unwrap().unwrap();
        assert_eq!(j.header.label, "bobyqa");
        assert_eq!(j.header.prior, 3);
        assert!(!j.header.racing.enabled, "racing-off header must parse as off");
        assert!(!j.finalized);
        assert_eq!(j.slices.len(), 2);
        assert!(!j.slices[0].external);
        assert!(j.slices[1].external);
        assert_eq!(j.slices[0].evals[1].0.to_bits(), vals[1].to_bits());
        assert_eq!(j.slices[0].evals[1].1, Fidelity::Full);
        for (r, got) in sp.ranges.iter().zip(&j.slices[0].evals[0].2) {
            assert_eq!(got.to_bits(), cfg.get(r.index).to_bits());
        }
        j.check_header(&st, &sp).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn racing_header_and_fidelity_markers_roundtrip() {
        let dir = tmp("racing");
        let path = journal_path(&dir, "tuning_log.csv");
        let sp = spec();
        let mut st = settings();
        st.racing = RacingSettings {
            enabled: true,
            eta: 3,
            min_tier_evals: 1,
        };
        let cfg = crate::config::params::HadoopConfig::default();
        let vals = [40.5_f64, 41.5, 42.5];
        let fids = [Fidelity::CostModel, Fidelity::Seeds(1), Fidelity::Full];
        let payload = slice_payload(false, &sp, &[cfg.clone(), cfg.clone(), cfg], &vals, &fids);
        assert!(payload.contains("@model") && payload.contains("@1"), "{payload}");
        journal_with(&[header_payload(&st, "bobyqa", &sp, 0), payload], &path);
        let j = Journal::load(&path).unwrap().unwrap();
        assert_eq!(j.header.racing, st.racing);
        let got: Vec<Fidelity> = j.slices[0].evals.iter().map(|e| e.1).collect();
        assert_eq!(got, fids);
        j.check_header(&st, &sp).unwrap();
        // racing drift is refused, like any other pinned setting
        let mut off = st.clone();
        off.racing = RacingSettings::default();
        let err = j.check_header(&off, &sp).unwrap_err();
        assert!(err.contains("different racing"), "{err}");
        // but eta drift between two racing-OFF runs is irrelevant
        let plain_header = header_payload(&off, "bobyqa", &sp, 0);
        assert_eq!(plain_header.split('\t').count(), 11, "racing-off header grew a field");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fin_marks_finalized_and_trailing_records_are_corruption() {
        let dir = tmp("fin");
        let path = journal_path(&dir, "tuning_log.csv");
        let header = header_payload(&settings(), "bobyqa", &spec(), 0);
        journal_with(&[header.clone(), FIN.to_string()], &path);
        assert!(Journal::load(&path).unwrap().unwrap().finalized);
        journal_with(&[header, FIN.to_string(), FIN.to_string()], &path);
        let err = Journal::load(&path).unwrap_err();
        assert!(err.contains("after fin"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn all_torn_is_none_and_settings_drift_is_refused() {
        let dir = tmp("drift");
        let path = journal_path(&dir, "tuning_log.csv");
        std::fs::write(&path, "half a torn header rec").unwrap();
        assert!(Journal::load(&path).unwrap().is_none());

        journal_with(&[header_payload(&settings(), "bobyqa", &spec(), 0)], &path);
        let j = Journal::load(&path).unwrap().unwrap();
        let mut changed = settings();
        changed.seed = 8;
        let err = j.check_header(&changed, &spec()).unwrap_err();
        assert!(err.contains("different seed"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Metrics extraction: parse downloaded artifacts back into numbers.
//!
//! "It is difficult for general users to execute a MapReduce job and
//! obtain metrics of performance after job completion" — this module is
//! the answering half: given a `downloaded_results/` folder it recovers
//! running time, phase milestones and counters from the history JSON.

use std::path::Path;

use crate::hadoop::joblogs::{parse_history, ParsedHistory};

/// Summary metrics of one completed job.
#[derive(Clone, Debug, PartialEq)]
pub struct JobMetrics {
    pub job_id: String,
    pub workload: String,
    pub runtime_s: f64,
    pub map_phase_s: f64,
    pub reduce_phase_s: f64,
    pub maps: u64,
    pub reduces: u64,
    pub failed_attempts: u64,
    pub data_local_fraction: f64,
    pub shuffle_mb: f64,
    pub config: Vec<(String, f64)>,
}

impl JobMetrics {
    pub fn from_history(h: &ParsedHistory) -> JobMetrics {
        let total_loc = h.counters.data_local_maps
            + h.counters.rack_local_maps
            + h.counters.off_rack_maps;
        JobMetrics {
            job_id: h.job_id.clone(),
            workload: h.workload.clone(),
            runtime_s: h.runtime_s,
            map_phase_s: h.map_phase_end_s,
            reduce_phase_s: (h.runtime_s - h.map_phase_end_s).max(0.0),
            maps: h.counters.total_maps,
            reduces: h.counters.total_reduces,
            failed_attempts: h.counters.failed_task_attempts,
            data_local_fraction: if total_loc > 0 {
                h.counters.data_local_maps as f64 / total_loc as f64
            } else {
                0.0
            },
            shuffle_mb: h.counters.shuffle_mb,
            config: h.config.clone(),
        }
    }

    /// Parse from a downloaded `history.json` file.
    pub fn from_file(path: &Path) -> Result<JobMetrics, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Ok(Self::from_history(&parse_history(&text)?))
    }

    /// Scan a `downloaded_results/` folder (or any folder with one or
    /// more `*history.json`) and parse every history document found.
    pub fn scan_dir(dir: &Path) -> Result<Vec<JobMetrics>, String> {
        let mut out = Vec::new();
        let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        let mut paths: Vec<_> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.ends_with("history.json"))
                    .unwrap_or(false)
            })
            .collect();
        paths.sort();
        for p in paths {
            out.push(Self::from_file(&p)?);
        }
        Ok(out)
    }

    /// Value of one Hadoop parameter in the job's configuration echo.
    pub fn config_value(&self, param: &str) -> Option<f64> {
        self.config
            .iter()
            .find(|(k, _)| k == param)
            .map(|(_, v)| *v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::hadoop::joblogs::to_history_json;
    use crate::hadoop::{simulate_job, ClusterSpec};
    use crate::workloads::wordcount;

    fn metrics() -> JobMetrics {
        let r = simulate_job(
            &ClusterSpec::default(),
            &wordcount(2048.0),
            &HadoopConfig::default(),
            1,
        );
        let text = to_history_json("job_42", &r).to_string();
        JobMetrics::from_history(&parse_history(&text).unwrap())
    }

    #[test]
    fn phases_partition_runtime() {
        let m = metrics();
        assert!(m.map_phase_s > 0.0);
        assert!(m.reduce_phase_s >= 0.0);
        assert!(m.map_phase_s <= m.runtime_s);
    }

    #[test]
    fn config_echo_readable() {
        let m = metrics();
        assert_eq!(m.config_value("mapreduce.job.reduces"), Some(1.0));
        assert!(m.config_value("not.a.param").is_none());
    }

    #[test]
    fn locality_fraction_in_unit_range() {
        let m = metrics();
        assert!((0.0..=1.0).contains(&m.data_local_fraction));
    }

    #[test]
    fn scan_dir_finds_histories() {
        let dir = std::env::temp_dir().join(format!("catla-scan-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let r = simulate_job(
            &ClusterSpec::default(),
            &wordcount(1024.0),
            &HadoopConfig::default(),
            2,
        );
        for i in 0..3 {
            std::fs::write(
                dir.join(format!("job_{i}.history.json")),
                to_history_json(&format!("job_{i}"), &r).to_string(),
            )
            .unwrap();
        }
        std::fs::write(dir.join("unrelated.txt"), "x").unwrap();
        let ms = JobMetrics::scan_dir(&dir).unwrap();
        assert_eq!(ms.len(), 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

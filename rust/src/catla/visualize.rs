//! Visualization — CSV out plus self-contained ASCII renderings of the
//! paper's two figure types (the paper defers to Minitab/MATLAB; CatlaUI
//! adds a runtime-vs-iteration line chart, which we render in the
//! terminal), and gnuplot scripts for camera-ready plots.

use crate::util::csv::Csv;

/// ASCII line chart of a (x, y) series — CatlaUI's convergence view.
pub fn line_chart(title: &str, series: &[(usize, f64)], width: usize, height: usize) -> String {
    if series.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let ys: Vec<f64> = series.iter().map(|(_, y)| *y).collect();
    let ymin = ys.iter().cloned().fold(f64::MAX, f64::min);
    let ymax = ys.iter().cloned().fold(f64::MIN, f64::max);
    let span = (ymax - ymin).max(1e-9);
    let width = width.max(8);
    let height = height.max(4);

    let mut grid = vec![vec![b' '; width]; height];
    let n = series.len();
    for (i, (_, y)) in series.iter().enumerate() {
        let col = if n == 1 { 0 } else { i * (width - 1) / (n - 1) };
        let row = ((ymax - y) / span * (height - 1) as f64).round() as usize;
        grid[row.min(height - 1)][col] = b'*';
    }
    let mut out = format!("{title}\n");
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            format!("{ymax:9.1} |")
        } else if r == height - 1 {
            format!("{ymin:9.1} |")
        } else {
            format!("{:>9} |", "")
        };
        out.push_str(&label);
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>10}+{}\n{:>11}iter 1 .. {}\n",
        "",
        "-".repeat(width),
        "",
        series.last().unwrap().0
    ));
    out
}

/// ASCII heat map of a 2-parameter surface (the terminal rendering of
/// the paper's Fig. 2 3-D surface). `rows`/`cols` are the axis values,
/// `z[r][c]` the runtime.
pub fn surface_heatmap(
    title: &str,
    row_name: &str,
    rows: &[f64],
    col_name: &str,
    cols: &[f64],
    z: &[Vec<f64>],
) -> String {
    const SHADES: &[u8] = b" .:-=+*#%@";
    let mut zmin = f64::MAX;
    let mut zmax = f64::MIN;
    for r in z {
        for &v in r {
            zmin = zmin.min(v);
            zmax = zmax.max(v);
        }
    }
    let span = (zmax - zmin).max(1e-9);
    let mut out = format!(
        "{title}\nrows: {row_name} ({} values)  cols: {col_name} ({} values)\n\
         shade: ' '(fast {zmin:.0}s) .. '@'(slow {zmax:.0}s)\n\n",
        rows.len(),
        cols.len()
    );
    for (ri, rv) in rows.iter().enumerate() {
        out.push_str(&format!("{rv:8.0} |"));
        for ci in 0..cols.len() {
            let t = (z[ri][ci] - zmin) / span;
            let idx = ((t * (SHADES.len() - 1) as f64).round() as usize).min(SHADES.len() - 1);
            out.push(SHADES[idx] as char);
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>9}+{}\n{:>10}{} from {:.0} to {:.0}\n",
        "",
        "-".repeat(cols.len()),
        "",
        col_name,
        cols.first().unwrap_or(&0.0),
        cols.last().unwrap_or(&0.0)
    ));
    out
}

/// Emit a gnuplot script regenerating Fig. 2 from its CSV.
pub fn gnuplot_fig2(csv_path: &str, out_png: &str) -> String {
    format!(
        "# gnuplot script — paper Fig. 2 surface\n\
         set datafile separator ','\n\
         set term pngcairo size 900,700\n\
         set output '{out_png}'\n\
         set dgrid3d 16,16\n\
         set hidden3d\n\
         set xlabel 'mapreduce.job.reduces'\n\
         set ylabel 'mapreduce.task.io.sort.mb'\n\
         set zlabel 'running time (s)'\n\
         splot '{csv_path}' every ::1 using 1:2:3 with lines title 'WordCount runtime'\n"
    )
}

/// Emit a gnuplot script regenerating Fig. 3 from a tuning log CSV.
pub fn gnuplot_fig3(csv_path: &str, out_png: &str) -> String {
    format!(
        "# gnuplot script — paper Fig. 3 convergence\n\
         set datafile separator ','\n\
         set term pngcairo size 900,500\n\
         set output '{out_png}'\n\
         set xlabel 'iteration'\n\
         set ylabel 'running time (s)'\n\
         plot '{csv_path}' every ::1 using 1:3 with linespoints title 'runtime', \\\n\
              '{csv_path}' every ::1 using 1:4 with lines lw 2 title 'best so far'\n"
    )
}

/// Render a tuning log CSV as the CatlaUI-style terminal chart.
pub fn chart_from_tuning_log(csv: &Csv) -> Result<String, String> {
    let iters = csv.col_f64("iter").ok_or("no iter column")?;
    let runtime = csv.col_f64("runtime_s").ok_or("no runtime_s column")?;
    let best = csv.col_f64("best_so_far").ok_or("no best_so_far column")?;
    let raw: Vec<(usize, f64)> = iters
        .iter()
        .zip(&runtime)
        .map(|(i, v)| (*i as usize, *v))
        .collect();
    let conv: Vec<(usize, f64)> = iters
        .iter()
        .zip(&best)
        .map(|(i, v)| (*i as usize, *v))
        .collect();
    Ok(format!(
        "{}\n{}",
        line_chart("running time per iteration", &raw, 60, 12),
        line_chart("best-so-far (convergence)", &conv, 60, 12)
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders_all_points() {
        let series: Vec<(usize, f64)> = (1..=20).map(|i| (i, 100.0 / i as f64)).collect();
        let s = line_chart("t", &series, 40, 10);
        assert!(s.contains('*'));
        assert!(s.lines().count() >= 10);
        // extremes labelled
        assert!(s.contains("100.0"));
        assert!(s.contains("5.0"));
    }

    #[test]
    fn line_chart_empty_and_single() {
        assert!(line_chart("t", &[], 40, 10).contains("no data"));
        let s = line_chart("t", &[(1, 5.0)], 40, 10);
        assert!(s.contains('*'));
    }

    #[test]
    fn heatmap_uses_full_shade_range() {
        let rows = vec![1.0, 2.0];
        let cols = vec![1.0, 2.0, 3.0];
        let z = vec![vec![10.0, 20.0, 30.0], vec![40.0, 50.0, 60.0]];
        let s = surface_heatmap("t", "r", &rows, "c", &cols, &z);
        assert!(s.contains(' '), "fastest shade missing");
        assert!(s.contains('@'), "slowest shade missing");
    }

    #[test]
    fn gnuplot_scripts_reference_files() {
        assert!(gnuplot_fig2("a.csv", "b.png").contains("a.csv"));
        assert!(gnuplot_fig3("x.csv", "y.png").contains("best so far"));
    }

    #[test]
    fn chart_from_log_round_trip() {
        let csv = Csv::parse(
            "iter,optimizer,runtime_s,best_so_far\n1,b,120,120\n2,b,100,100\n3,b,110,100\n",
        )
        .unwrap();
        let s = chart_from_tuning_log(&csv).unwrap();
        assert!(s.contains("convergence"));
    }
}

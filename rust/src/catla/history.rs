//! The `/history` folder: CSV summaries of every run in a project.
//!
//! "After job completion, the summaries of job metrics are in the sub
//! folder /history of the project root ... you can visualize the results
//! from the information of *.csv files" (§II.C.5). Tuning logs are
//! written incrementally per evaluation so an interrupted run can be
//! re-aggregated (§II.C.4) or resumed.

use std::path::{Path, PathBuf};

use crate::catla::metrics::JobMetrics;
use crate::config::spec::TuningSpec;
use crate::optim::result::{EvalRecord, TuningOutcome};
use crate::util::csv::Csv;
use crate::util::durable;

pub const JOBS_CSV: &str = "jobs.csv";
pub const TUNING_CSV: &str = "tuning_log.csv";
pub const SUMMARY_CSV: &str = "summary.csv";

/// Handle over a project's history directory.
pub struct History {
    pub dir: PathBuf,
}

impl History {
    pub fn open(project_dir: &Path) -> std::io::Result<History> {
        let dir = project_dir.join("history");
        std::fs::create_dir_all(&dir)?;
        Ok(History { dir })
    }

    fn jobs_header() -> Vec<&'static str> {
        vec![
            "job_id",
            "workload",
            "runtime_s",
            "map_phase_s",
            "reduce_phase_s",
            "maps",
            "reduces",
            "failed_attempts",
            "data_local_fraction",
            "shuffle_mb",
        ]
    }

    /// Append one completed job to `jobs.csv` (creates it on first use).
    /// Append-only with write-header-once semantics: the header goes in
    /// via an exclusive create, each row is one O_APPEND write — so
    /// concurrent writers (sharded sweeps, parallel serve sessions)
    /// interleave rows instead of clobbering each other through the old
    /// read-modify-rewrite.
    pub fn append_job(&self, m: &JobMetrics) -> Result<(), String> {
        let row = vec![
            m.job_id.clone(),
            m.workload.clone(),
            format!("{:.3}", m.runtime_s),
            format!("{:.3}", m.map_phase_s),
            format!("{:.3}", m.reduce_phase_s),
            m.maps.to_string(),
            m.reduces.to_string(),
            m.failed_attempts.to_string(),
            format!("{:.4}", m.data_local_fraction),
            format!("{:.1}", m.shuffle_mb),
        ];
        let header: Vec<String> = Self::jobs_header().iter().map(|s| s.to_string()).collect();
        Self::append_row(
            &self.dir.join(JOBS_CSV),
            &header,
            &row,
            "jobs.mid-append",
            "jobs.csv header mismatch (written by a different Catla version?)",
        )
    }

    /// The shared append-only CSV primitive: exclusive-create the file
    /// with its header (first writer wins), validate an existing file's
    /// header, then append the row as a single durable write. The
    /// `mid_point` crash hook can tear the row append in half — which is
    /// exactly the torn tail [`Csv::load_tolerant`] and `catla fsck`
    /// repair.
    fn append_row(
        path: &std::path::Path,
        header: &[String],
        row: &[String],
        mid_point: &str,
        mismatch_err: &str,
    ) -> Result<(), String> {
        let header_line = Csv::render_row(header);
        let row_line = Csv::render_row(row);
        let created = durable::create_excl(path, header_line.as_bytes()).map_err(|e| e.to_string())?;
        if !created {
            let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
            match text.lines().next() {
                // a zero-length leftover (crashed before the header
                // write landed): seed the header through the append
                None => durable::append_bytes(path, header_line.as_bytes(), mid_point)
                    .map_err(|e| e.to_string())?,
                Some(first) if first != header_line.trim_end() => {
                    return Err(mismatch_err.into());
                }
                Some(_) => {}
            }
        }
        durable::append_bytes(path, row_line.as_bytes(), mid_point).map_err(|e| e.to_string())
    }

    pub fn load_jobs(&self) -> Result<Csv, String> {
        Csv::load(&self.dir.join(JOBS_CSV))
    }

    fn tuning_header(spec: &TuningSpec) -> Vec<String> {
        let mut h = vec![
            "iter".to_string(),
            "optimizer".to_string(),
            "runtime_s".to_string(),
            "best_so_far".to_string(),
        ];
        for r in &spec.ranges {
            h.push(r.name().to_string());
        }
        h
    }

    /// Write (overwrite) the full tuning log for an outcome.
    pub fn write_tuning_log(
        &self,
        spec: &TuningSpec,
        outcome: &TuningOutcome,
    ) -> Result<PathBuf, String> {
        self.write_tuning_log_to(TUNING_CSV, spec, outcome)
    }

    /// Write a tuning log under a caller-chosen file name — sharded
    /// sweeps (`catla sweep --shard k/n`) write one log per shard so
    /// independent processes never clobber each other's history. Column
    /// layout is identical to [`History::write_tuning_log`]; for scoped
    /// merged spaces the per-workload dims appear as their
    /// `<param>@<workload>` aliases, which is what lets resume-style
    /// replay reconstruct the exact merged space from the log alone.
    pub fn write_tuning_log_to(
        &self,
        file_name: &str,
        spec: &TuningSpec,
        outcome: &TuningOutcome,
    ) -> Result<PathBuf, String> {
        self.write_tuning_records_to(file_name, spec, &outcome.optimizer, &outcome.records)
    }

    /// Write a tuning log from bare records, before a [`TuningOutcome`]
    /// exists — the serve daemon checkpoints every in-flight session this
    /// way after each completed slice, so a killed daemon resumes through
    /// the normal replay machinery. Row/column layout is byte-identical
    /// to [`History::write_tuning_log_to`] on the finished outcome.
    pub fn write_tuning_records_to(
        &self,
        file_name: &str,
        spec: &TuningSpec,
        optimizer: &str,
        records: &[EvalRecord],
    ) -> Result<PathBuf, String> {
        let path = self.dir.join(file_name);
        let mut header = Self::tuning_header(spec);
        // racing runs carry an extra fidelity column; a log whose every
        // record is full fidelity stays byte-identical to the pre-racing
        // layout (and keeps feeding older readers unchanged)
        let with_fidelity = records.iter().any(|r| !r.fidelity.is_full());
        if with_fidelity {
            header.push("fidelity".to_string());
        }
        let mut csv = Csv {
            header: header.clone(),
            rows: Vec::new(),
        };
        for rec in records {
            let mut row = vec![
                rec.iter.to_string(),
                optimizer.to_string(),
                format!("{:.3}", rec.value),
                format!("{:.3}", rec.best_so_far),
            ];
            for r in &spec.ranges {
                row.push(format!("{}", rec.config.get(r.index)));
            }
            if with_fidelity {
                row.push(rec.fidelity.label());
            }
            csv.push_row(row);
        }
        csv.save(&path).map_err(|e| e.to_string())?;
        Ok(path)
    }

    fn summary_header(spec: &TuningSpec) -> Vec<String> {
        let mut header = vec![
            "optimizer".to_string(),
            "evals".to_string(),
            "best_runtime_s".to_string(),
        ];
        for r in &spec.ranges {
            header.push(format!("best.{}", r.name()));
        }
        header
    }

    fn summary_row(spec: &TuningSpec, outcome: &TuningOutcome) -> Vec<String> {
        let mut row = vec![
            outcome.optimizer.clone(),
            outcome.evals().to_string(),
            format!("{:.3}", outcome.best_value),
        ];
        for r in &spec.ranges {
            row.push(format!("{}", outcome.best_config.get(r.index)));
        }
        row
    }

    /// Append a summary row (one per tuning run) to `summary.csv`.
    /// Append-only, write-header-once: concurrent runs (sharded sweeps,
    /// parallel serve sessions) each add their row with a single
    /// O_APPEND write, so the old read-modify-rewrite lost-update — two
    /// finishers both loading N rows and both writing back N+1 — cannot
    /// happen.
    pub fn append_summary(
        &self,
        spec: &TuningSpec,
        outcome: &TuningOutcome,
    ) -> Result<(), String> {
        Self::append_row(
            &self.dir.join(SUMMARY_CSV),
            &Self::summary_header(spec),
            &Self::summary_row(spec, outcome),
            "summary.mid-append",
            "summary.csv header mismatch (different params.spec?)",
        )
    }

    /// Crash-recovery variant of [`History::append_summary`]: repair a
    /// torn final line first, then append the outcome's row only if that
    /// exact rendered row is not already present. Used when resuming a
    /// `fin`-marked journal — the crash landed somewhere between "final
    /// log durable" and "journal removed", so the summary row may have
    /// been written zero times, torn in half, or written completely.
    /// Returns whether a row was appended.
    pub fn append_summary_if_missing(
        &self,
        spec: &TuningSpec,
        outcome: &TuningOutcome,
    ) -> Result<bool, String> {
        self.append_summary_row_if_missing(
            &Self::summary_header(spec),
            &Self::summary_row(spec, outcome),
        )
    }

    /// Row-level [`History::append_summary_if_missing`] — `catla fsck`
    /// reconstructs the row from a journal rather than a live outcome.
    pub fn append_summary_row_if_missing(
        &self,
        header: &[String],
        row: &[String],
    ) -> Result<bool, String> {
        let path = self.dir.join(SUMMARY_CSV);
        let row_line = Csv::render_row(row);
        if path.is_file() {
            let bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
            if !bytes.is_empty() && !bytes.ends_with(b"\n") {
                // torn mid-append: drop the partial final line
                let keep = bytes.iter().rposition(|&b| b == b'\n').map(|i| i + 1).unwrap_or(0);
                durable::truncate_to(&path, keep as u64).map_err(|e| e.to_string())?;
            }
            let text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
            if text.lines().any(|l| l == row_line.trim_end()) {
                return Ok(false);
            }
        }
        Self::append_row(
            &path,
            header,
            row,
            "summary.mid-append",
            "summary.csv header mismatch (different params.spec?)",
        )?;
        Ok(true)
    }

    /// Load the tuning log back (resume / aggregate / visualize).
    pub fn load_tuning_log(&self) -> Result<Csv, String> {
        Csv::load(&self.dir.join(TUNING_CSV))
    }

    /// Crash-tolerant tuning-log load: a torn final line (killed
    /// mid-write) is dropped and reported as a warning instead of
    /// failing the parse. See [`Csv::load_tolerant`].
    pub fn load_tuning_log_tolerant(&self) -> Result<(Csv, Option<String>), String> {
        Csv::load_tolerant(&self.dir.join(TUNING_CSV))
    }

    /// Convergence series (iter, best_so_far) from a stored log.
    pub fn convergence_from_log(csv: &Csv) -> Result<Vec<(usize, f64)>, String> {
        let iters = csv.col_f64("iter").ok_or("no iter column")?;
        let best = csv.col_f64("best_so_far").ok_or("no best_so_far column")?;
        Ok(iters
            .into_iter()
            .zip(best)
            .map(|(i, b)| (i as usize, b))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::params::HadoopConfig;
    use crate::optim::result::Recorder;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-hist-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn outcome(spec: &TuningSpec, values: &[f64]) -> TuningOutcome {
        let mut rec = Recorder::new();
        for (i, v) in values.iter().enumerate() {
            let mut cfg = HadoopConfig::default();
            cfg.set(spec.ranges[0].index, 2.0 + i as f64 * 2.0);
            rec.record(vec![0.5; spec.dims()], cfg, *v);
        }
        rec.finish("bobyqa")
    }

    #[test]
    fn tuning_log_roundtrip() {
        let dir = tmp("log");
        let h = History::open(&dir).unwrap();
        let spec = TuningSpec::fig2();
        let out = outcome(&spec, &[120.0, 100.0, 110.0, 90.0]);
        h.write_tuning_log(&spec, &out).unwrap();
        let csv = h.load_tuning_log().unwrap();
        assert_eq!(csv.rows.len(), 4);
        let conv = History::convergence_from_log(&csv).unwrap();
        assert_eq!(conv.last().unwrap().1, 90.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fidelity_column_appears_only_on_racing_logs() {
        use crate::optim::result::Fidelity;
        let dir = tmp("fidelity");
        let h = History::open(&dir).unwrap();
        let spec = TuningSpec::fig2();
        // all-full log: pre-racing layout, no fidelity column
        let full = outcome(&spec, &[120.0, 100.0]);
        h.write_tuning_log(&spec, &full).unwrap();
        assert!(h.load_tuning_log().unwrap().col_index("fidelity").is_none());
        // a pruned record brings the column in, rendered via label()
        let mut rec = Recorder::new();
        rec.record_tiered(vec![0.5; spec.dims()], HadoopConfig::default(), 130.0, Fidelity::Full);
        rec.record_tiered(
            vec![0.5; spec.dims()],
            HadoopConfig::default(),
            99.0,
            Fidelity::Seeds(1),
        );
        let raced = rec.finish("random");
        h.write_tuning_log(&spec, &raced).unwrap();
        let csv = h.load_tuning_log().unwrap();
        let fi = csv.col_index("fidelity").expect("racing log missing fidelity column");
        assert_eq!(csv.rows[0][fi], "full");
        assert_eq!(csv.rows[1][fi], "1");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summary_appends_across_runs() {
        let dir = tmp("summary");
        let h = History::open(&dir).unwrap();
        let spec = TuningSpec::fig2();
        h.append_summary(&spec, &outcome(&spec, &[120.0, 100.0])).unwrap();
        h.append_summary(&spec, &outcome(&spec, &[130.0, 95.0])).unwrap();
        let csv = Csv::load(&h.dir.join(SUMMARY_CSV)).unwrap();
        assert_eq!(csv.rows.len(), 2);
        assert_eq!(csv.col_f64("best_runtime_s").unwrap(), vec![100.0, 95.0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summary_recovery_repairs_torn_tail_and_appends_once() {
        let dir = tmp("summary-recover");
        let h = History::open(&dir).unwrap();
        let spec = TuningSpec::fig2();
        let done = outcome(&spec, &[120.0, 100.0]);
        h.append_summary(&spec, &done).unwrap();

        // already present → no duplicate row
        assert!(!h.append_summary_if_missing(&spec, &done).unwrap());
        assert_eq!(Csv::load(&h.dir.join(SUMMARY_CSV)).unwrap().rows.len(), 1);

        // torn mid-append (partial final line, no newline) → repaired,
        // then the missing row is appended exactly once
        let path = h.dir.join(SUMMARY_CSV);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"bobyqa,2,99.9"); // torn half-row
        std::fs::write(&path, &bytes).unwrap();
        let other = outcome(&spec, &[130.0, 95.0]);
        assert!(h.append_summary_if_missing(&spec, &other).unwrap());
        let csv = Csv::load(&path).unwrap();
        assert_eq!(csv.rows.len(), 2);
        assert_eq!(csv.col_f64("best_runtime_s").unwrap(), vec![100.0, 95.0]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summary_header_mismatch_is_a_hard_error() {
        let dir = tmp("summary-mismatch");
        let h = History::open(&dir).unwrap();
        let spec = TuningSpec::fig2();
        std::fs::write(h.dir.join(SUMMARY_CSV), "who,what\n").unwrap();
        let err = h.append_summary(&spec, &outcome(&spec, &[120.0])).unwrap_err();
        assert!(err.contains("summary.csv header mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn jobs_csv_accumulates() {
        let dir = tmp("jobs");
        let h = History::open(&dir).unwrap();
        let m = JobMetrics {
            job_id: "job_1".into(),
            workload: "wordcount".into(),
            runtime_s: 100.0,
            map_phase_s: 60.0,
            reduce_phase_s: 40.0,
            maps: 80,
            reduces: 8,
            failed_attempts: 0,
            data_local_fraction: 0.9,
            shuffle_mb: 1000.0,
            config: vec![],
        };
        h.append_job(&m).unwrap();
        h.append_job(&m).unwrap();
        assert_eq!(h.load_jobs().unwrap().rows.len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

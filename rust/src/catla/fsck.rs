//! `catla fsck <dir>`: validate (and with `--repair`, fix) a project's
//! history directory after a crash.
//!
//! Everything Catla persists is either atomically replaced or
//! append-only (see `util/durable.rs`), so the only damage a kill at any
//! instant can leave behind is *suffix* damage — a stray `.tmp` sibling,
//! a torn final CSV line, a torn final journal record — plus at most one
//! in-doubt summary row for a `fin`-marked journal. fsck classifies
//! exactly those cases as repairable; anything else (a bad record with a
//! valid one after it, a ragged interior CSV row) cannot be produced by
//! a crash and is reported as a hard problem, never silently "fixed".
//!
//! Repairs, per finding:
//! * stray `.<name>.tmp` → removed (the rename never happened; the real
//!   file is either the old version or the new one, both consistent);
//! * torn final CSV line → file truncated back to the last newline;
//! * torn final journal record → journal truncated to its clean prefix;
//! * journal with no complete record (the crash tore the very first,
//!   header append) → removed;
//! * non-finalized journal → *materialized*: the checkpoint is rendered
//!   to its plain tuning CSV (byte-identical to what the session's own
//!   finalize would write for those evaluations) and the journal
//!   retired, so legacy CSV resume, `aggregate` and `ui` all see the
//!   work; this is also the escape hatch when tuning settings changed
//!   underneath a journal (re-drive would refuse);
//! * finalized journal (`fin` present: the final CSV is already durable)
//!   → the summary row is appended if missing, then the journal retired.

use std::fmt;
use std::path::Path;

use crate::catla::history::History;
use crate::catla::journal::{Journal, JOURNAL_SUFFIX};
use crate::optim::result::Fidelity;
use crate::util::csv::Csv;
use crate::util::durable;

/// What a scan found and (optionally) fixed.
#[derive(Debug, Default)]
pub struct FsckReport {
    /// Files examined.
    pub scanned: usize,
    /// Repairs applied (only ever non-zero with `repair = true`).
    pub repaired: usize,
    /// Repairable findings (torn tails, stray tmp files, pending
    /// journals) — informational without `--repair`.
    pub warnings: Vec<String>,
    /// Hard corruption that cannot be crash damage; fsck refuses to
    /// guess and the CLI exits non-zero while any remain.
    pub problems: Vec<String>,
}

impl fmt::Display for FsckReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "fsck: {} file(s) scanned, {} repair(s) applied, {} warning(s), {} problem(s)",
            self.scanned,
            self.repaired,
            self.warnings.len(),
            self.problems.len()
        )?;
        for w in &self.warnings {
            writeln!(f, "warning {w}")?;
        }
        for p in &self.problems {
            writeln!(f, "problem {p}")?;
        }
        Ok(())
    }
}

/// One record of a materialized checkpoint: the folded runtime plus the
/// per-parameter display cells, in log-column order.
struct MatRec {
    value: f64,
    fid: Fidelity,
    cells: Vec<String>,
}

/// Rebuild the evaluation sequence a journal checkpoints, exactly as the
/// live session records it: the CSV prior prefix (values re-parsed from
/// the rounded log, like `DriverSession::replay` does), then the slice
/// evals in order with the driver's early-stop rule applied — a told
/// slice may contain evals past the stopping point, which the driver
/// never records.
fn materialized_records(
    j: &Journal,
    prior_rows: &[Vec<String>],
    prior_fids: &[Fidelity],
    vi: usize,
    dims: &[usize],
) -> Result<Vec<MatRec>, String> {
    let mut recs = Vec::new();
    for (k, row) in prior_rows.iter().enumerate() {
        let value: f64 = row[vi].parse().map_err(|_| "bad runtime cell in prior log row")?;
        recs.push(MatRec {
            value,
            fid: prior_fids.get(k).copied().unwrap_or(Fidelity::Full),
            cells: dims.iter().map(|&i| row[i].clone()).collect(),
        });
    }
    // stall accounting and the running best consider full-fidelity evals
    // only, exactly like the live session's tell_values_tiered
    let mut best = recs
        .iter()
        .filter(|r| r.fid.is_full())
        .map(|r| r.value)
        .fold(f64::INFINITY, f64::min);
    let mut stall = 0usize;
    let patience = j.header.early_patience;
    'slices: for slice in &j.slices {
        for (value, fid, cfg) in &slice.evals {
            if fid.is_full() {
                if patience > 0 {
                    if *value < best * (1.0 - j.header.early_tol) {
                        stall = 0;
                    } else {
                        stall += 1;
                    }
                }
                best = best.min(*value);
            }
            recs.push(MatRec {
                value: *value,
                fid: *fid,
                cells: cfg.iter().map(|v| format!("{v}")).collect(),
            });
            if patience > 0 && stall >= patience {
                break 'slices;
            }
        }
    }
    Ok(recs)
}

/// Render a journal's checkpoint as the plain tuning CSV its session
/// would write — same columns, same `{:.3}` rounding, same running
/// best — and atomically replace `log_path` with it.
fn materialize_log(j: &Journal, log_path: &Path) -> Result<(), String> {
    let mut header = vec![
        "iter".to_string(),
        "optimizer".to_string(),
        "runtime_s".to_string(),
        "best_so_far".to_string(),
    ];
    header.extend(j.header.params.iter().cloned());

    // the prior prefix comes from the existing log's clean rows
    let (prior_rows, prior_fids): (Vec<Vec<String>>, Vec<Fidelity>) = if j.header.prior > 0 {
        let (csv, _torn) = Csv::load_tolerant(log_path)
            .map_err(|e| format!("prior log needed by the journal is unreadable: {e}"))?;
        if csv.rows.len() < j.header.prior {
            return Err(format!(
                "journal expects {} prior rows but the log has only {}",
                j.header.prior,
                csv.rows.len()
            ));
        }
        let vi = csv
            .col_index("runtime_s")
            .ok_or("prior log missing runtime_s")?;
        let fi = csv.col_index("fidelity");
        let dims: Vec<usize> = j
            .header
            .params
            .iter()
            .map(|p| {
                csv.col_index(p)
                    .ok_or_else(|| format!("prior log missing column {p}"))
            })
            .collect::<Result<_, _>>()?;
        // re-order the prior cells into the journal's column order
        let rows: Vec<Vec<String>> = csv.rows[..j.header.prior]
            .iter()
            .map(|row| {
                let mut out = vec![row[vi].clone()];
                out.extend(dims.iter().map(|&i| row[i].clone()));
                out
            })
            .collect();
        let fids: Vec<Fidelity> = csv.rows[..j.header.prior]
            .iter()
            .map(|row| match fi {
                Some(i) => Fidelity::parse(&row[i]),
                None => Ok(Fidelity::Full),
            })
            .collect::<Result<_, _>>()?;
        (rows, fids)
    } else {
        (Vec::new(), Vec::new())
    };
    // prior_rows now hold [runtime, params...]; adapt indices
    let recs = materialized_records(
        j,
        &prior_rows,
        &prior_fids,
        0,
        &(1..=j.header.params.len()).collect::<Vec<_>>(),
    )?;

    // same conditional column rule as History::write_tuning_records_to
    let with_fidelity = recs.iter().any(|r| !r.fid.is_full());
    if with_fidelity {
        header.push("fidelity".to_string());
    }
    let mut csv = Csv {
        header,
        rows: Vec::new(),
    };
    // best-so-far mirrors Recorder::record_tiered: only full-fidelity
    // values compete; a pruned row shows the current full best (or its
    // own value before any full record exists)
    let mut best: Option<f64> = None;
    for (i, r) in recs.iter().enumerate() {
        let bsf = match best {
            None => r.value,
            Some(b) if r.fid.is_full() => b.min(r.value),
            Some(b) => b,
        };
        if r.fid.is_full() {
            best = Some(bsf);
        }
        let mut row = vec![
            (i + 1).to_string(),
            j.header.label.clone(),
            format!("{:.3}", r.value),
            format!("{bsf:.3}"),
        ];
        row.extend(r.cells.iter().cloned());
        if with_fidelity {
            row.push(r.fid.label());
        }
        csv.push_row(row);
    }
    csv.save(log_path).map_err(|e| e.to_string())
}

/// Append the summary row a finalized journal's crashed finalize may not
/// have gotten to (exactly-once: skipped when the rendered row already
/// exists).
fn complete_summary(j: &Journal, history: &History, log_path: &Path) -> Result<bool, String> {
    let mut header = vec![
        "optimizer".to_string(),
        "evals".to_string(),
        "best_runtime_s".to_string(),
    ];
    header.extend(j.header.params.iter().map(|p| format!("best.{p}")));

    let (prior_rows, prior_fids): (Vec<Vec<String>>, Vec<Fidelity>) = if j.header.prior > 0 {
        let (csv, _torn) = Csv::load_tolerant(log_path)
            .map_err(|e| format!("final log needed by the journal is unreadable: {e}"))?;
        let vi = csv.col_index("runtime_s").ok_or("final log missing runtime_s")?;
        let fi = csv.col_index("fidelity");
        let dims: Vec<usize> = j
            .header
            .params
            .iter()
            .map(|p| csv.col_index(p).ok_or_else(|| format!("final log missing column {p}")))
            .collect::<Result<_, _>>()?;
        if csv.rows.len() < j.header.prior {
            return Err(format!(
                "journal expects {} prior rows but the log has only {}",
                j.header.prior,
                csv.rows.len()
            ));
        }
        let rows: Vec<Vec<String>> = csv.rows[..j.header.prior]
            .iter()
            .map(|row| {
                let mut out = vec![row[vi].clone()];
                out.extend(dims.iter().map(|&i| row[i].clone()));
                out
            })
            .collect();
        let fids: Vec<Fidelity> = csv.rows[..j.header.prior]
            .iter()
            .map(|row| match fi {
                Some(i) => Fidelity::parse(&row[i]),
                None => Ok(Fidelity::Full),
            })
            .collect::<Result<_, _>>()?;
        (rows, fids)
    } else {
        (Vec::new(), Vec::new())
    };
    let recs = materialized_records(
        j,
        &prior_rows,
        &prior_fids,
        0,
        &(1..=j.header.params.len()).collect::<Vec<_>>(),
    )?;
    // the declared best is full-fidelity evidence, with the same
    // defensive all-pruned fallback as Recorder::finish
    let best = recs
        .iter()
        .filter(|r| r.fid.is_full())
        .min_by(|a, b| a.value.total_cmp(&b.value))
        .or_else(|| recs.iter().min_by(|a, b| a.value.total_cmp(&b.value)))
        .ok_or("finalized journal holds no evaluations")?;
    let mut row = vec![
        j.header.label.clone(),
        recs.len().to_string(),
        format!("{:.3}", best.value),
    ];
    row.extend(best.cells.iter().cloned());
    history.append_summary_row_if_missing(&header, &row)
}

/// Scan (and with `repair`, fix) one project directory's history. The
/// project root is accepted too — fsck looks at `<dir>/history` if it
/// exists, else treats `<dir>` itself as the history directory.
pub fn fsck_dir(dir: &Path, repair: bool) -> Result<FsckReport, String> {
    let hist_dir = if dir.join("history").is_dir() {
        dir.join("history")
    } else {
        dir.to_path_buf()
    };
    let mut report = FsckReport::default();
    if !hist_dir.is_dir() {
        report
            .warnings
            .push(format!("{}: no history directory", hist_dir.display()));
        return Ok(report);
    }
    // deterministic scan order (read_dir order is filesystem-dependent);
    // a CSV sorts before its `<csv>.journal` sibling, so torn logs are
    // repaired before the journal that reads them is processed
    let mut names: Vec<String> = std::fs::read_dir(&hist_dir)
        .map_err(|e| format!("{}: {e}", hist_dir.display()))?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().is_file())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();

    for name in &names {
        let path = hist_dir.join(name);
        report.scanned += 1;

        // stray atomic-write staging file: the rename never happened
        if name.starts_with('.') && name.ends_with(".tmp") {
            report.warnings.push(format!(
                "{}: stray atomic-write staging file (crash between write and rename)",
                path.display()
            ));
            if repair {
                std::fs::remove_file(&path).map_err(|e| e.to_string())?;
                report.repaired += 1;
            }
            continue;
        }

        if let Some(log_name) = name.strip_suffix(JOURNAL_SUFFIX) {
            let log_path = hist_dir.join(log_name);
            match Journal::load(&path) {
                Err(e) => report.problems.push(e),
                Ok(None) => {
                    report.warnings.push(format!(
                        "{}: journal with no complete record (crash tore the first append)",
                        path.display()
                    ));
                    if repair {
                        std::fs::remove_file(&path).map_err(|e| e.to_string())?;
                        report.repaired += 1;
                    }
                }
                Ok(Some(j)) => {
                    if j.torn_bytes > 0 {
                        report.warnings.push(format!(
                            "{}: torn final journal record ({} bytes)",
                            path.display(),
                            j.torn_bytes
                        ));
                        if repair {
                            durable::truncate_to(&path, j.clean_len).map_err(|e| e.to_string())?;
                            report.repaired += 1;
                        }
                    }
                    let history = History {
                        dir: hist_dir.clone(),
                    };
                    if j.finalized {
                        report.warnings.push(format!(
                            "{}: finalized journal pending cleanup (summary row may be missing)",
                            path.display()
                        ));
                        if repair {
                            match complete_summary(&j, &history, &log_path) {
                                Ok(_appended) => {
                                    std::fs::remove_file(&path).map_err(|e| e.to_string())?;
                                    durable::fsync_dir(&hist_dir);
                                    report.repaired += 1;
                                }
                                Err(e) => report.problems.push(format!("{}: {e}", path.display())),
                            }
                        }
                    } else {
                        report.warnings.push(format!(
                            "{}: interrupted-session journal ({} slice(s)); reopen in `catla serve` \
                             to resume exactly, or --repair to materialize the checkpoint log",
                            path.display(),
                            j.slices.len()
                        ));
                        if repair {
                            match materialize_log(&j, &log_path) {
                                Ok(()) => {
                                    std::fs::remove_file(&path).map_err(|e| e.to_string())?;
                                    durable::fsync_dir(&hist_dir);
                                    report.repaired += 1;
                                }
                                Err(e) => report.problems.push(format!("{}: {e}", path.display())),
                            }
                        }
                    }
                }
            }
            continue;
        }

        if name.ends_with(".csv") {
            match Csv::load_tolerant(&path) {
                Err(e) => report
                    .problems
                    .push(format!("{}: {e} (mid-file corruption, not crash damage)", path.display())),
                Ok((_csv, Some(warn))) => {
                    report.warnings.push(warn);
                    if repair {
                        let bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
                        let keep = bytes
                            .iter()
                            .rposition(|&b| b == b'\n')
                            .map(|i| i + 1)
                            .unwrap_or(0);
                        durable::truncate_to(&path, keep as u64).map_err(|e| e.to_string())?;
                        report.repaired += 1;
                    }
                }
                Ok((_csv, None)) => {}
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::history::TUNING_CSV;
    use crate::catla::journal;
    use crate::catla::optimizer_runner::TuningSettings;
    use crate::config::params::HadoopConfig;
    use crate::config::spec::TuningSpec;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-fsck-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(d.join("history")).unwrap();
        d
    }

    fn settings() -> TuningSettings {
        TuningSettings {
            optimizer: "bobyqa".into(),
            budget: 8,
            repeats: 1,
            seed: 7,
            prescreen: false,
            early_patience: 0,
            early_tol: 1e-3,
            batch_chunk: 8,
            cache_entries: None,
            retry_max: 0,
            retry_backoff_ms: 0,
            racing: Default::default(),
        }
    }

    fn write_journal(dir: &Path, finalized: bool) -> PathBuf {
        let spec = TuningSpec::fig2();
        let hist = dir.join("history");
        let jpath = journal::journal_path(&hist, TUNING_CSV);
        let mut cfg = HadoopConfig::default();
        cfg.set(spec.ranges[0].index, 8.0);
        durable::append_framed(&jpath, &journal::header_payload(&settings(), "bobyqa", &spec, 0), "x").unwrap();
        durable::append_framed(
            &jpath,
            &journal::slice_payload(false, &spec, &[cfg.clone()], &[120.5], &[Fidelity::Full]),
            "x",
        )
        .unwrap();
        let mut cfg2 = cfg.clone();
        cfg2.set(spec.ranges[0].index, 12.0);
        durable::append_framed(
            &jpath,
            &journal::slice_payload(false, &spec, &[cfg2], &[98.25], &[Fidelity::Full]),
            "x",
        )
        .unwrap();
        if finalized {
            durable::append_framed(&jpath, journal::FIN, "x").unwrap();
        }
        jpath
    }

    #[test]
    fn clean_history_scans_clean() {
        let dir = tmp("clean");
        std::fs::write(dir.join("history").join(TUNING_CSV), "iter,optimizer,runtime_s,best_so_far\n").unwrap();
        let r = fsck_dir(&dir, false).unwrap();
        assert!(r.warnings.is_empty() && r.problems.is_empty(), "{r}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repair_materializes_an_interrupted_journal() {
        let dir = tmp("materialize");
        let jpath = write_journal(&dir, false);

        // dry run: reported, nothing touched
        let r = fsck_dir(&dir, false).unwrap();
        assert_eq!(r.repaired, 0);
        assert!(r.warnings.iter().any(|w| w.contains("interrupted-session journal")), "{r}");
        assert!(jpath.is_file());

        let r = fsck_dir(&dir, true).unwrap();
        assert!(r.problems.is_empty(), "{r}");
        assert!(r.repaired > 0);
        assert!(!jpath.is_file(), "repair must retire the journal");
        let csv = Csv::load(&dir.join("history").join(TUNING_CSV)).unwrap();
        assert_eq!(csv.rows.len(), 2);
        assert_eq!(csv.rows[0][2], "120.500");
        assert_eq!(csv.rows[1][3], "98.250", "running best not recomputed");
        assert_eq!(csv.rows[1][1], "bobyqa");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repair_completes_a_finalized_journal_summary_exactly_once() {
        let dir = tmp("fin-summary");
        write_journal(&dir, true);
        // the final log fin guarantees is durable — materialize it here
        // the same way the crashed finalize would have
        std::fs::write(
            dir.join("history").join(TUNING_CSV),
            "iter,optimizer,runtime_s,best_so_far,mapreduce.job.reduces,mapreduce.task.io.sort.mb\n\
             1,bobyqa,120.500,120.500,8,100\n2,bobyqa,98.250,98.250,12,100\n",
        )
        .unwrap();
        let r = fsck_dir(&dir, true).unwrap();
        assert!(r.problems.is_empty(), "{r}");
        let summary = std::fs::read_to_string(dir.join("history").join(SUMMARY_CSV)).unwrap();
        assert_eq!(summary.lines().count(), 2, "header + exactly one row:\n{summary}");
        assert!(summary.lines().nth(1).unwrap().starts_with("bobyqa,2,98.250"), "{summary}");
        // a second repair pass finds a clean directory
        let r = fsck_dir(&dir, true).unwrap();
        assert_eq!(r.repaired, 0, "{r}");
        assert_eq!(
            std::fs::read_to_string(dir.join("history").join(SUMMARY_CSV)).unwrap(),
            summary,
            "summary must not grow on re-fsck"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tails_and_stray_tmp_are_repaired_corruption_is_not() {
        let dir = tmp("torn");
        let hist = dir.join("history");
        std::fs::write(hist.join("aux_log.csv"), "iter,runtime_s\n1,120.5\n2,98.").unwrap();
        std::fs::write(hist.join(".summary.csv.tmp"), "half-staged").unwrap();
        let jpath = write_journal(&dir, false);
        // tear the journal's final record mid-line
        let bytes = std::fs::read(&jpath).unwrap();
        std::fs::write(&jpath, &bytes[..bytes.len() - 7]).unwrap();

        let r = fsck_dir(&dir, true).unwrap();
        assert!(r.problems.is_empty(), "{r}");
        assert!(!hist.join(".summary.csv.tmp").exists());
        assert_eq!(
            std::fs::read_to_string(hist.join("aux_log.csv")).unwrap(),
            "iter,runtime_s\n1,120.5\n",
            "torn CSV tail must be truncated byte-exactly"
        );
        // journal survived with one clean slice and was then materialized
        let csv = Csv::load(&hist.join(TUNING_CSV)).unwrap();
        assert_eq!(csv.rows.len(), 1, "only the clean journal prefix materializes");

        // mid-file corruption: flip a byte in the FIRST journal record
        // while a valid one follows — must be a problem, not a repair
        let jpath = write_journal(&dir, false);
        let mut bytes = std::fs::read(&jpath).unwrap();
        bytes[2] ^= 0xFF;
        std::fs::write(&jpath, &bytes).unwrap();
        let r = fsck_dir(&dir, false).unwrap();
        assert!(!r.problems.is_empty(), "corruption slipped through: {r}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

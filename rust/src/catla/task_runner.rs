//! Task Runner — "submits a single MapReduce job to a Hadoop cluster and
//! obtains its analyzing results and logs after the job is completed.
//! This component provides the basis of Project Runner and Optimizer
//! Runner." (§II.A)

use std::path::PathBuf;

use crate::catla::history::History;
use crate::catla::metrics::JobMetrics;
use crate::catla::project::Project;
use crate::config::params::HadoopConfig;
use crate::hadoop::{Cluster, JobStatus, JobSubmission};
use crate::util::durable::atomic_write;

/// Outcome of one Task-Runner execution.
#[derive(Clone, Debug)]
pub struct TaskRunOutcome {
    pub job_id: String,
    pub metrics: JobMetrics,
    /// Where artifacts were downloaded (`<project>/downloaded_results`).
    pub results_dir: PathBuf,
    pub polls: u32,
}

pub struct TaskRunner<'a, C: Cluster> {
    pub cluster: &'a mut C,
    /// Cap on poll iterations before declaring the job hung.
    pub max_polls: u32,
}

impl<'a, C: Cluster> TaskRunner<'a, C> {
    pub fn new(cluster: &'a mut C) -> Self {
        Self {
            cluster,
            max_polls: 10_000,
        }
    }

    /// Run the project's job with an explicit configuration.
    pub fn run_with_config(
        &mut self,
        project: &Project,
        config: &HadoopConfig,
    ) -> Result<TaskRunOutcome, String> {
        let workload = project.workload()?;
        let name = project.job.get("name").unwrap_or("job").to_string();
        let submission = JobSubmission {
            name,
            workload,
            config: config.clone(),
        };
        let job_id = self.cluster.submit_job(submission)?;

        // poll until completion (SimCluster completes after a few polls;
        // a real SSH cluster would take minutes)
        let mut polls = 0;
        loop {
            polls += 1;
            if polls > self.max_polls {
                return Err(format!("job {job_id} did not finish after {polls} polls"));
            }
            match self.cluster.poll(&job_id)? {
                JobStatus::Running { .. } => continue,
                JobStatus::Failed { reason } => {
                    return Err(format!("job {job_id} failed: {reason}"))
                }
                JobStatus::Succeeded { .. } => break,
            }
        }

        // download artifacts into the project folder (paper Step 5)
        let results_dir = project.results_dir();
        let logs_dir = results_dir.join("logs");
        std::fs::create_dir_all(&logs_dir).map_err(|e| e.to_string())?;
        let artifacts = self.cluster.fetch_artifacts(&job_id)?;
        let history_path = results_dir.join(format!("{job_id}.history.json"));
        atomic_write(&history_path, artifacts.history_json.as_bytes()).map_err(|e| e.to_string())?;
        for (name, content) in &artifacts.container_logs {
            atomic_write(&logs_dir.join(name), content.as_bytes()).map_err(|e| e.to_string())?;
        }
        for (name, content) in &artifacts.outputs {
            atomic_write(&results_dir.join(name), content.as_bytes()).map_err(|e| e.to_string())?;
        }

        // parse metrics and append to /history
        let metrics = JobMetrics::from_file(&history_path)?;
        let history = History::open(&project.dir).map_err(|e| e.to_string())?;
        history.append_job(&metrics)?;

        Ok(TaskRunOutcome {
            job_id,
            metrics,
            results_dir,
            polls,
        })
    }

    /// Run with the project's own base configuration (the plain
    /// `catla task -dir ...` flow).
    pub fn run(&mut self, project: &Project) -> Result<TaskRunOutcome, String> {
        let cfg = project.base_config()?;
        self.run_with_config(project, &cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catla::project::{create_template, ProjectKind};
    use crate::hadoop::{ClusterSpec, SimCluster};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-task-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn paper_step_walkthrough() {
        // Steps 1-5 of §II.B.2 against the simulated cluster
        let dir = tmp("wordcount");
        create_template(&dir, ProjectKind::Task, "wordcount", 2048.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::from_env(&project.env));
        let mut runner = TaskRunner::new(&mut cluster);
        let out = runner.run(&project).unwrap();

        // Step 5: downloaded_results exists and holds the artifacts
        assert!(out.results_dir.is_dir());
        assert!(out.results_dir.join(format!("{}.history.json", out.job_id)).is_file());
        assert!(out.results_dir.join("logs").is_dir());
        assert!(out.metrics.runtime_s > 0.0);
        assert!(out.polls >= 2, "poll loop not exercised");

        // history/jobs.csv got a row
        let h = History::open(&dir).unwrap();
        assert_eq!(h.load_jobs().unwrap().rows.len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repeated_runs_accumulate_history() {
        let dir = tmp("repeat");
        create_template(&dir, ProjectKind::Task, "grep", 512.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut runner = TaskRunner::new(&mut cluster);
        runner.run(&project).unwrap();
        runner.run(&project).unwrap();
        let h = History::open(&dir).unwrap();
        assert_eq!(h.load_jobs().unwrap().rows.len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn explicit_config_reaches_the_cluster() {
        let dir = tmp("cfg");
        create_template(&dir, ProjectKind::Task, "wordcount", 1024.0).unwrap();
        let project = Project::load(&dir).unwrap();
        let mut cluster = SimCluster::new(ClusterSpec::default());
        let mut runner = TaskRunner::new(&mut cluster);
        let mut cfg = HadoopConfig::default();
        cfg.set_by_name("mapreduce.job.reduces", 16.0).unwrap();
        let out = runner.run_with_config(&project, &cfg).unwrap();
        assert_eq!(out.metrics.config_value("mapreduce.job.reduces"), Some(16.0));
        assert_eq!(out.metrics.reduces, 16);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

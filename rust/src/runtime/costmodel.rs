//! Typed wrapper over the batched cost model: `HadoopConfig -> predicted
//! runtime (+ phase breakdown)` scoring in f32.
//!
//! With the `pjrt` feature, two fixed-shape executables (N=128 and
//! N=1024, from spec.AOT_BATCH_SIZES) are compiled once; arbitrary batch
//! sizes are served by padding up to the smallest fitting artifact and
//! chunking above the largest (padding rows repeat the last config and
//! are sliced away). The default build computes the identical numbers
//! from the native rust mirror of the model.

use crate::config::params::HadoopConfig;
use crate::hadoop::ClusterSpec;
use crate::optim::surrogate::CandidateScorer;
use crate::runtime::Runtime;
use crate::workloads::WorkloadSpec;

pub const N_PHASES: usize = 8;
pub const N_CONSTS: usize = 16;
/// Batch sizes baked into the artifacts (keep in sync with spec.py).
pub const BATCH_SIZES: [usize; 2] = [128, 1024];

/// Row-major default calibration matrix as f32 (mirror of spec.py).
pub fn default_weights_f32() -> [f32; N_PHASES * N_PHASES] {
    let w = crate::hadoop::costmodel::default_weights();
    let mut out = [0f32; N_PHASES * N_PHASES];
    for i in 0..N_PHASES {
        for j in 0..N_PHASES {
            out[i * N_PHASES + j] = w[i][j] as f32;
        }
    }
    out
}

#[cfg(feature = "pjrt")]
pub struct CostModelExec {
    exes: Vec<(usize, xla::PjRtLoadedExecutable)>, // (batch, exe), ascending
    consts: [f32; N_CONSTS],
    weights: [f32; N_PHASES * N_PHASES],
    /// Executions performed (for perf accounting).
    pub calls: u64,
}

#[cfg(feature = "pjrt")]
impl CostModelExec {
    /// Compile the cost-model artifacts for a (workload, cluster) pair.
    pub fn load(rt: &Runtime, wl: &WorkloadSpec, cl: &ClusterSpec) -> Result<Self, String> {
        let mut exes = Vec::new();
        for n in BATCH_SIZES {
            let exe = rt.compile_artifact(&format!("costmodel_n{n}.hlo.txt"))?;
            exes.push((n, exe));
        }
        Ok(Self {
            exes,
            consts: cl.to_consts(wl),
            weights: default_weights_f32(),
            calls: 0,
        })
    }

    /// Re-target another workload/cluster without recompiling.
    pub fn set_context(&mut self, wl: &WorkloadSpec, cl: &ClusterSpec) {
        self.consts = cl.to_consts(wl);
    }

    /// Predict runtimes for arbitrary batch sizes. Returns seconds per
    /// config, aligned with the input order.
    pub fn predict(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f32>, String> {
        Ok(self.predict_with_phases(cfgs)?.0)
    }

    /// Predict runtimes and the per-phase breakdown.
    pub fn predict_with_phases(
        &mut self,
        cfgs: &[HadoopConfig],
    ) -> Result<(Vec<f32>, Vec<[f32; N_PHASES]>), String> {
        if cfgs.is_empty() {
            return Ok((Vec::new(), Vec::new()));
        }
        let mut runtimes = Vec::with_capacity(cfgs.len());
        let mut phases = Vec::with_capacity(cfgs.len());
        let max_batch = self.exes.last().unwrap().0;
        for chunk in cfgs.chunks(max_batch) {
            let (r, p) = self.predict_chunk(chunk)?;
            runtimes.extend(r);
            phases.extend(p);
        }
        Ok((runtimes, phases))
    }

    fn predict_chunk(
        &mut self,
        cfgs: &[HadoopConfig],
    ) -> Result<(Vec<f32>, Vec<[f32; N_PHASES]>), String> {
        use crate::config::params::N_AOT_PARAMS;
        use crate::runtime::{execute_tuple, literal_f32};

        let n = cfgs.len();
        // smallest artifact that fits
        let (batch, exe) = self
            .exes
            .iter()
            .find(|(b, _)| *b >= n)
            .ok_or_else(|| format!("chunk {n} exceeds max artifact batch"))?;
        let batch = *batch;

        let mut flat = Vec::with_capacity(batch * N_AOT_PARAMS);
        for c in cfgs {
            flat.extend_from_slice(&c.to_f32_row());
        }
        let last = cfgs[n - 1].to_f32_row();
        for _ in n..batch {
            flat.extend_from_slice(&last); // pad with the last row
        }

        let lit_cfg = literal_f32(&flat, &[batch as i64, N_AOT_PARAMS as i64])?;
        let lit_consts = literal_f32(&self.consts, &[N_CONSTS as i64])?;
        let lit_w = literal_f32(&self.weights, &[N_PHASES as i64, N_PHASES as i64])?;

        let out = execute_tuple(exe, &[lit_cfg, lit_consts, lit_w])?;
        self.calls += 1;
        if out.len() != 2 {
            return Err(format!("cost model returned {}-tuple, expected 2", out.len()));
        }
        let runtime: Vec<f32> = out[0].to_vec().map_err(|e| format!("runtime out: {e}"))?;
        let ph_flat: Vec<f32> = out[1].to_vec().map_err(|e| format!("phases out: {e}"))?;
        let mut phases = Vec::with_capacity(n);
        for i in 0..n {
            let mut row = [0f32; N_PHASES];
            row.copy_from_slice(&ph_flat[i * N_PHASES..(i + 1) * N_PHASES]);
            phases.push(row);
        }
        Ok((runtime[..n].to_vec(), phases))
    }
}

/// Native fallback: the rust mirror of the cost model, f32 like the
/// artifacts. Same API, zero dependencies; batch sizes are unbounded.
#[cfg(not(feature = "pjrt"))]
pub struct CostModelExec {
    wl: WorkloadSpec,
    cl: ClusterSpec,
    /// Batch evaluations performed (for perf accounting).
    pub calls: u64,
}

#[cfg(not(feature = "pjrt"))]
impl CostModelExec {
    /// Bind the cost model to a (workload, cluster) pair. The `Runtime`
    /// is only consulted for its artifact directory (which must exist so
    /// both backends share the same setup story).
    pub fn load(_rt: &Runtime, wl: &WorkloadSpec, cl: &ClusterSpec) -> Result<Self, String> {
        Ok(Self {
            wl: wl.clone(),
            cl: cl.clone(),
            calls: 0,
        })
    }

    /// Re-target another workload/cluster.
    pub fn set_context(&mut self, wl: &WorkloadSpec, cl: &ClusterSpec) {
        self.wl = wl.clone();
        self.cl = cl.clone();
    }

    /// Predict runtimes for arbitrary batch sizes. Returns seconds per
    /// config, aligned with the input order.
    pub fn predict(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f32>, String> {
        Ok(self.predict_with_phases(cfgs)?.0)
    }

    /// Predict runtimes and the per-phase breakdown.
    pub fn predict_with_phases(
        &mut self,
        cfgs: &[HadoopConfig],
    ) -> Result<(Vec<f32>, Vec<[f32; N_PHASES]>), String> {
        use crate::hadoop::costmodel;
        if cfgs.is_empty() {
            return Ok((Vec::new(), Vec::new()));
        }
        self.calls += 1;
        let mut runtimes = Vec::with_capacity(cfgs.len());
        let mut phases = Vec::with_capacity(cfgs.len());
        for c in cfgs {
            runtimes.push(costmodel::predict_runtime(c, &self.wl, &self.cl) as f32);
            let ph = costmodel::predict_phases(c, &self.wl, &self.cl);
            let mut row = [0f32; N_PHASES];
            for (k, v) in ph.iter().enumerate() {
                row[k] = *v as f32;
            }
            phases.push(row);
        }
        Ok((runtimes, phases))
    }
}

impl CandidateScorer for CostModelExec {
    fn score(&mut self, cfgs: &[HadoopConfig]) -> Result<Vec<f64>, String> {
        Ok(self.predict(cfgs)?.into_iter().map(|v| v as f64).collect())
    }

    fn name(&self) -> &str {
        if cfg!(feature = "pjrt") {
            "pjrt-costmodel"
        } else {
            "native-costmodel"
        }
    }
}

//! Typed wrapper over the batched-quadratic evaluator: q(x) = c + g·x +
//! ½xᵀHx over candidate batches — the DFO surrogate's inner op.
//!
//! With the `pjrt` feature this executes the AOT artifact (fixed shape
//! N=256 candidates, D=8 dims; smaller problems are zero-padded —
//! provably neutral for a quadratic, see
//! python/tests/test_kernel.py::test_zero_padding_is_neutral). The
//! default build computes the same values natively in f32.

use crate::runtime::Runtime;

pub const QUAD_BATCH: usize = 256;
pub const QUAD_DIM: usize = 8;

#[cfg(feature = "pjrt")]
pub struct QuadraticExec {
    exe: xla::PjRtLoadedExecutable,
    pub calls: u64,
}

#[cfg(feature = "pjrt")]
impl QuadraticExec {
    pub fn load(rt: &Runtime) -> Result<Self, String> {
        Ok(Self {
            exe: rt.compile_artifact(&format!("quadratic_n{QUAD_BATCH}.hlo.txt"))?,
            calls: 0,
        })
    }

    /// Evaluate the quadratic at each row of `xs` (dim d ≤ QUAD_DIM).
    /// `g` is length d, `h` row-major d×d, `c0` the constant term.
    pub fn eval(
        &mut self,
        xs: &[Vec<f64>],
        g: &[f64],
        h: &[Vec<f64>],
        c0: f64,
    ) -> Result<Vec<f64>, String> {
        use crate::runtime::{execute_tuple, literal_f32};

        let d = g.len();
        check_shapes(xs, g, h)?;
        let mut out = Vec::with_capacity(xs.len());
        // pad g and h once
        let mut gp = [0f32; QUAD_DIM];
        for (i, v) in g.iter().enumerate() {
            gp[i] = *v as f32;
        }
        let mut hp = [0f32; QUAD_DIM * QUAD_DIM];
        for i in 0..d {
            for j in 0..d {
                hp[i * QUAD_DIM + j] = h[i][j] as f32;
            }
        }
        for chunk in xs.chunks(QUAD_BATCH) {
            let n = chunk.len();
            let mut flat = vec![0f32; QUAD_BATCH * QUAD_DIM];
            for (r, x) in chunk.iter().enumerate() {
                for (c, v) in x.iter().enumerate() {
                    flat[r * QUAD_DIM + c] = *v as f32;
                }
            }
            let lits = [
                literal_f32(&flat, &[QUAD_BATCH as i64, QUAD_DIM as i64])?,
                literal_f32(&gp, &[QUAD_DIM as i64])?,
                literal_f32(&hp, &[QUAD_DIM as i64, QUAD_DIM as i64])?,
                literal_f32(&[c0 as f32], &[1])?,
            ];
            let res = execute_tuple(&self.exe, &lits)?;
            self.calls += 1;
            let q: Vec<f32> = res[0].to_vec().map_err(|e| format!("quad out: {e}"))?;
            out.extend(q[..n].iter().map(|v| *v as f64));
        }
        Ok(out)
    }
}

/// Native fallback: the same batched quadratic computed in f32 directly.
#[cfg(not(feature = "pjrt"))]
pub struct QuadraticExec {
    pub calls: u64,
}

#[cfg(not(feature = "pjrt"))]
impl QuadraticExec {
    pub fn load(_rt: &Runtime) -> Result<Self, String> {
        Ok(Self { calls: 0 })
    }

    /// Evaluate the quadratic at each row of `xs` (dim d ≤ QUAD_DIM).
    /// `g` is length d, `h` row-major d×d, `c0` the constant term.
    pub fn eval(
        &mut self,
        xs: &[Vec<f64>],
        g: &[f64],
        h: &[Vec<f64>],
        c0: f64,
    ) -> Result<Vec<f64>, String> {
        let d = g.len();
        check_shapes(xs, g, h)?;
        let mut out = Vec::with_capacity(xs.len());
        for chunk in xs.chunks(QUAD_BATCH) {
            self.calls += 1; // one "execution" per artifact-sized batch
            for x in chunk {
                // mirror the artifact's f32 arithmetic
                let mut q = c0 as f32;
                for i in 0..d {
                    q += (g[i] as f32) * (x[i] as f32);
                    for j in 0..d {
                        q += 0.5 * (x[i] as f32) * (h[i][j] as f32) * (x[j] as f32);
                    }
                }
                out.push(q as f64);
            }
        }
        Ok(out)
    }
}

/// Shared input validation for both backends.
fn check_shapes(xs: &[Vec<f64>], g: &[f64], h: &[Vec<f64>]) -> Result<(), String> {
    let d = g.len();
    if d > QUAD_DIM {
        return Err(format!("dimension {d} exceeds artifact dim {QUAD_DIM}"));
    }
    if h.len() != d || h.iter().any(|r| r.len() != d) {
        return Err("hessian shape mismatch".into());
    }
    for (r, x) in xs.iter().enumerate() {
        if x.len() != d {
            return Err(format!("candidate {r} has dim {}, expected {d}", x.len()));
        }
    }
    Ok(())
}

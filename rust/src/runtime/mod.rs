//! XLA PJRT runtime: load the AOT artifacts produced by
//! `python/compile/aot.py` and execute them from the tuning hot path.
//!
//! The interchange format is HLO **text** (see DESIGN.md / aot.py — the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos). Each
//! artifact is compiled exactly once per process; executions reuse the
//! compiled `PjRtLoadedExecutable`, so the request path never touches
//! Python, files, or the compiler.

pub mod costmodel;
pub mod quadratic;

pub use costmodel::CostModelExec;
pub use quadratic::QuadraticExec;

use std::path::{Path, PathBuf};

/// Shared PJRT client + artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    /// CPU PJRT client over the given artifacts directory.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime, String> {
        let artifacts_dir = artifacts_dir.into();
        if !artifacts_dir.is_dir() {
            return Err(format!(
                "artifacts directory {} does not exist — run `make artifacts`",
                artifacts_dir.display()
            ));
        }
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            artifacts_dir,
        })
    }

    /// Resolve the artifacts directory: `$CATLA_ARTIFACTS`, else
    /// `./artifacts`, else `<crate root>/artifacts`.
    pub fn default_artifacts_dir() -> PathBuf {
        if let Ok(d) = std::env::var("CATLA_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let local = PathBuf::from("artifacts");
        if local.is_dir() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Open the default runtime (most callers).
    pub fn open_default() -> Result<Runtime, String> {
        Self::new(Self::default_artifacts_dir())
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_artifact(&self, file: &str) -> Result<xla::PjRtLoadedExecutable, String> {
        let path = self.artifacts_dir.join(file);
        compile_hlo_text(&self.client, &path)
    }
}

/// Load HLO text from `path` and compile it on `client`.
pub fn compile_hlo_text(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable, String> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| format!("parsing {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| format!("compiling {}: {e}", path.display()))
}

/// Execute a compiled artifact on literal inputs and return the tuple
/// elements (aot.py lowers with `return_tuple=True`).
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>, String> {
    let result = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| format!("execute: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| format!("to_literal: {e}"))?;
    lit.to_tuple().map_err(|e| format!("to_tuple: {e}"))
}

/// Build an f32 literal of the given shape from row-major data.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal, String> {
    let expect: i64 = dims.iter().product();
    if expect != data.len() as i64 {
        return Err(format!(
            "shape {dims:?} wants {expect} elements, got {}",
            data.len()
        ));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| format!("reshape{dims:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = match Runtime::new("/nonexistent/artifacts") {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing dir"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn literal_shape_mismatch_detected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    // Artifact-dependent tests live in rust/tests/runtime_integration.rs
    // (they require `make artifacts` to have run).
}

//! Batched config-scoring runtime behind one API, two backends:
//!
//! * **`pjrt` feature ON** — load the AOT artifacts produced by
//!   `python/compile/aot.py` and execute them through XLA PJRT. The
//!   interchange format is HLO **text** (see DESIGN.md / aot.py — the
//!   crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos). Each
//!   artifact is compiled exactly once per process; executions reuse the
//!   compiled `PjRtLoadedExecutable`, so the request path never touches
//!   Python, files, or the compiler. Requires vendoring the `xla` crate.
//! * **default (native)** — the same `CostModelExec` / `QuadraticExec`
//!   types computed by the rust mirror of the cost model, in f32 like the
//!   artifacts, with zero external dependencies. The offline image builds
//!   this; `rust/tests/runtime_integration.rs` pins the two backends to
//!   each other.

pub mod costmodel;
pub mod quadratic;

pub use costmodel::CostModelExec;
pub use quadratic::QuadraticExec;

use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::path::Path;

/// Shared runtime handle: artifact directory plus (with `pjrt`) the PJRT
/// client the executables compile onto.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    /// Open a runtime over the given artifacts directory.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime, String> {
        let artifacts_dir = artifacts_dir.into();
        if !artifacts_dir.is_dir() {
            return Err(format!(
                "artifacts directory {} does not exist — run `make artifacts`",
                artifacts_dir.display()
            ));
        }
        Self::open_backend(artifacts_dir)
    }

    #[cfg(feature = "pjrt")]
    fn open_backend(artifacts_dir: PathBuf) -> Result<Runtime, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            artifacts_dir,
        })
    }

    #[cfg(not(feature = "pjrt"))]
    fn open_backend(artifacts_dir: PathBuf) -> Result<Runtime, String> {
        Ok(Runtime { artifacts_dir })
    }

    /// Resolve the artifacts directory: `$CATLA_ARTIFACTS`, else
    /// `./artifacts`, else `<crate root>/artifacts`.
    pub fn default_artifacts_dir() -> PathBuf {
        // detlint: allow(ambient-entropy) -- artifact-directory discovery
        // at open; not on any simulation or tuning-decision path
        if let Ok(d) = std::env::var("CATLA_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let local = PathBuf::from("artifacts");
        if local.is_dir() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Open the default runtime (most callers).
    pub fn open_default() -> Result<Runtime, String> {
        Self::new(Self::default_artifacts_dir())
    }

    /// Which backend serves executions.
    pub fn backend(&self) -> &'static str {
        if cfg!(feature = "pjrt") {
            "pjrt"
        } else {
            "native"
        }
    }

    /// Load + compile one HLO-text artifact.
    #[cfg(feature = "pjrt")]
    pub fn compile_artifact(&self, file: &str) -> Result<xla::PjRtLoadedExecutable, String> {
        let path = self.artifacts_dir.join(file);
        compile_hlo_text(&self.client, &path)
    }
}

/// Load HLO text from `path` and compile it on `client`.
#[cfg(feature = "pjrt")]
pub fn compile_hlo_text(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable, String> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| format!("parsing {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| format!("compiling {}: {e}", path.display()))
}

/// Execute a compiled artifact on literal inputs and return the tuple
/// elements (aot.py lowers with `return_tuple=True`).
#[cfg(feature = "pjrt")]
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>, String> {
    let result = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| format!("execute: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| format!("to_literal: {e}"))?;
    lit.to_tuple().map_err(|e| format!("to_tuple: {e}"))
}

/// Build an f32 literal of the given shape from row-major data.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal, String> {
    let expect: i64 = dims.iter().product();
    if expect != data.len() as i64 {
        return Err(format!(
            "shape {dims:?} wants {expect} elements, got {}",
            data.len()
        ));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| format!("reshape{dims:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = match Runtime::new("/nonexistent/artifacts") {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing dir"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_shape_mismatch_detected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    // Backend-agreement tests live in rust/tests/runtime_integration.rs.
}

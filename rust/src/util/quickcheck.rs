//! Miniature property-testing driver (proptest is unavailable offline).
//!
//! `forall` runs a property over `cases` random inputs produced by a
//! generator closure; on failure it reports the case index and the seed
//! that reproduces it (re-run with `CATLA_QC_SEED=<seed>`). A light
//! shrinking pass retries the failing case with "smaller" regenerated
//! inputs when the generator supports a size hint.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct QcConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for QcConfig {
    fn default() -> Self {
        // detlint: allow(ambient-entropy) -- opt-in repro override for the
        // property harness; the fixed default seed keeps unconfigured runs
        // deterministic
        let seed = std::env::var("CATLA_QC_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        // detlint: allow(ambient-entropy) -- case-count knob for local deep
        // runs; never changes which seed a given case index uses
        let cases = std::env::var("CATLA_QC_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Self { cases, seed }
    }
}

/// Run `prop` over `cases` inputs from `gen`. Panics (test failure) with
/// the reproducing seed and a Debug dump of the failing input.
pub fn forall<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    forall_cfg(name, QcConfig::default(), gen, prop)
}

pub fn forall_cfg<T, G, P>(name: &str, cfg: QcConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case}/{}:\n  {msg}\n  \
                 input: {input:#?}\n  reproduce with CATLA_QC_SEED={} CATLA_QC_CASES=1",
                cfg.cases, case_seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("add-commutes", |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        forall("always-fails", |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut seen1 = Vec::new();
        let mut seen2 = Vec::new();
        let cfg = QcConfig { cases: 16, seed: 42 };
        forall_cfg("collect1", cfg.clone(), |r| r.next_u64(), |&x| {
            seen1.push(x);
            Ok(())
        });
        forall_cfg("collect2", cfg, |r| r.next_u64(), |&x| {
            seen2.push(x);
            Ok(())
        });
        assert_eq!(seen1, seen2);
    }
}

//! Fixed-size thread pool (tokio is unavailable offline).
//!
//! Catla's Project Runner and the benchmark harness evaluate independent
//! cluster jobs concurrently; `map_parallel` spawns a throwaway pool,
//! preserves input order and propagates panics. Hot loops that evaluate
//! many batches (the ask/tell `ClusterObjective`) instead keep ONE
//! [`ThreadPool`] alive and run each batch through
//! [`ThreadPool::scoped_run`], which lets workers borrow the caller's
//! state — no per-item clones, no per-call thread spawn/join.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Raw slot pointer that may cross into pool workers —
/// [`ThreadPool::scoped_run`] guarantees disjoint writes and a bounded
/// lifetime.
struct SendPtr<T>(*mut T);

// SAFETY: a SendPtr only ever wraps the base of a caller-owned buffer
// handed to `scoped_run_slots`, which (a) hands each worker a disjoint
// element range (slot `w` / indices claimed through one atomic counter),
// and (b) blocks until every worker is done before the borrow it erased
// ends — so sending the pointer to a worker never creates an aliased or
// dangling access. `T: Send` carries the payload's own thread-safety.
unsafe impl<T: Send> Send for SendPtr<T> {}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}

impl<T> Copy for SendPtr<T> {}

/// A simple work-stealing-free pool: one shared queue, N workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("catla-pool-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool worker hung up");
    }

    /// Scoped parallel map over `0..n` on the pool's PERSISTENT workers:
    /// returns `[f(0), …, f(n-1)]` in index order. Unlike
    /// [`map_parallel`] this neither spawns threads nor requires
    /// `'static` — `f` may borrow the caller's state, because the call
    /// blocks until every worker task has finished, so no borrow
    /// escapes. At most `max_workers` of the pool's workers participate;
    /// indices are claimed from a shared atomic counter, so an uneven
    /// per-index cost self-balances. Worker panics are re-raised here
    /// (after all tasks have stopped touching the shared state).
    pub fn scoped_run<R, F>(&self, n: usize, max_workers: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        // Vec<()> is zero-sized storage — this adds no allocation
        self.scoped_run_with(n, max_workers, &mut Vec::new(), || (), |_: &mut (), i| f(i))
    }

    /// [`ThreadPool::scoped_run`] with per-worker SCRATCH state: each
    /// participating worker gets exclusive `&mut` access to one slot of
    /// `scratch` for the whole call, and the slots live in the caller —
    /// so expensive worker-local state (e.g. a simulation arena) is
    /// created once (`init`, called only to grow `scratch` up to the
    /// worker count) and reused across every subsequent call. Slot 0 is
    /// also the slot the single-worker fast path uses, so serial and
    /// parallel callers share warm state.
    pub fn scoped_run_with<S, R, I, F>(
        &self,
        n: usize,
        max_workers: usize,
        scratch: &mut Vec<S>,
        mut init: I,
        f: F,
    ) -> Vec<R>
    where
        S: Send,
        R: Send,
        I: FnMut() -> S,
        F: Fn(&mut S, usize) -> R + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let workers = self.size().min(max_workers.max(1)).min(n);
        while scratch.len() < workers {
            scratch.push(init());
        }
        self.scoped_run_slots(n, &mut scratch[..workers], f)
    }

    /// The slot-level core of [`ThreadPool::scoped_run_with`]: run
    /// `0..n` over at most `slots.len()` of the pool's workers, each
    /// participating worker holding exclusive `&mut` access to its slot
    /// for the whole call. The caller owns the slots outright (a plain
    /// `&mut [S]`, no grow-on-demand) — which is what lets long-lived
    /// owners like the serve dispatcher size their arena pool ONCE and
    /// bound memory for the daemon's lifetime, instead of letting every
    /// call site grow a `Vec`. Worker count = `size().min(slots.len())
    /// .min(n)`; one worker runs serially on slot 0.
    pub fn scoped_run_slots<S, R, F>(&self, n: usize, slots: &mut [S], f: F) -> Vec<R>
    where
        S: Send,
        R: Send,
        F: Fn(&mut S, usize) -> R + Sync,
    {
        self.try_scoped_run_slots(n, slots, f)
            .into_iter()
            .map(|r| match r {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            })
            .collect()
    }

    /// [`ThreadPool::scoped_run_slots`] with PER-INDEX panic isolation:
    /// `f` panicking on index `i` yields `Err(payload)` in position `i`
    /// while every other index still runs to completion — the worker
    /// that caught the panic simply claims the next index, and its
    /// scratch slot stays live. This is the crash-tolerance primitive
    /// the serve daemon builds on: one poisoned evaluation must fail
    /// only its own session, never the batch, the workers, or the
    /// daemon. The caller decides what a panic means; `scoped_run_slots`
    /// keeps the historical re-raise behavior on top of this.
    pub fn try_scoped_run_slots<S, R, F>(
        &self,
        n: usize,
        slots: &mut [S],
        f: F,
    ) -> Vec<thread::Result<R>>
    where
        S: Send,
        R: Send,
        F: Fn(&mut S, usize) -> R + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        assert!(!slots.is_empty(), "scoped_run_slots needs at least one scratch slot");
        let workers = self.size().min(slots.len()).min(n);
        if workers == 1 {
            let s = &mut slots[0];
            return (0..n)
                .map(|i| std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut *s, i))))
                .collect();
        }
        let scratch = slots;
        let mut slots: Vec<Option<thread::Result<R>>> = (0..n).map(|_| None).collect();
        let next = AtomicUsize::new(0);
        let (done_tx, done_rx) = mpsc::channel::<thread::Result<()>>();
        let slots_ptr = SendPtr(slots.as_mut_ptr());
        let scratch_ptr = SendPtr(scratch.as_mut_ptr());
        for w in 0..workers {
            let done_tx = done_tx.clone();
            let f = &f;
            let next = &next;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // SAFETY: job w is spawned exactly once and
                    // `w < workers <= scratch.len()`, so slot w is this
                    // job's exclusive &mut for the whole call; the call
                    // blocks below until every job is done, so the slot
                    // outlives this reference.
                    let s = unsafe { &mut *scratch_ptr.0.add(w) };
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // per-index isolation: a panicking f poisons only
                        // index i; this worker and its scratch slot carry
                        // on with the next index
                        let v =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(s, i)));
                        // SAFETY: each index `i < n` is claimed by
                        // exactly one worker via the shared `next`
                        // counter, so this write targets a distinct
                        // element of the n-long results buffer and never
                        // aliases; the buffer outlives the blocking call.
                        unsafe { *slots_ptr.0.add(i) = Some(v) };
                    }
                }));
                let _ = done_tx.send(r);
            });
            // SAFETY (lifetime erasure): the pool's job type is
            // `'static`, but every borrow the job holds outlives it —
            // this function blocks on exactly `workers` completion
            // messages below before reading `slots`/`scratch` or
            // returning, so no job can run (or exist) past the borrowed
            // scope.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            self.execute(job);
        }
        drop(done_tx);
        let mut panic = None;
        for _ in 0..workers {
            match done_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(p)) => panic = Some(p),
                // every job sends exactly one message (the send is
                // outside catch_unwind's closure body but cannot panic)
                Err(_) => unreachable!("scoped_run worker vanished"),
            }
        }
        if let Some(p) = panic {
            // a panic OUTSIDE f (infrastructure, not workload): results
            // may be incomplete, so re-raise rather than return holes
            std::panic::resume_unwind(p);
        }
        slots
            .into_iter()
            .map(|s| s.expect("unclaimed scoped_run slot"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over `items` on `threads` workers; results keep input order.
/// Panics in workers are re-raised here.
pub fn map_parallel<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();
    {
        let pool = ThreadPool::new(threads);
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            pool.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
    } // pool drop joins workers
    drop(tx);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx.iter() {
        match r {
            Ok(v) => slots[i] = Some(v),
            Err(p) => std::panic::resume_unwind(p),
        }
    }
    slots.into_iter().map(|s| s.expect("missing result")).collect()
}

/// Default parallelism for host-side work.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let out = map_parallel((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let out: Vec<i32> = map_parallel(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock sleeps observe nothing under Miri's scheduler
    fn runs_concurrently() {
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        map_parallel((0..16).collect::<Vec<_>>(), 8, |_| {
            let live = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(live, Ordering::SeqCst);
            thread::sleep(std::time::Duration::from_millis(20));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) > 1, "no observed concurrency");
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        map_parallel(vec![1, 2, 3], 2, |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn scoped_run_borrows_and_keeps_order() {
        // f borrows a local — the whole point of the scoped variant
        let inputs: Vec<u64> = (0..257).map(|i| i * 3).collect();
        let pool = ThreadPool::new(8);
        let out = pool.scoped_run(inputs.len(), 8, |i| inputs[i] + 1);
        assert_eq!(out, inputs.iter().map(|x| x + 1).collect::<Vec<_>>());
        // the SAME pool serves later batches (persistent workers)
        let out2 = pool.scoped_run(10, 4, |i| inputs[i]);
        assert_eq!(out2, inputs[..10].to_vec());
        // empty + singleton fast paths
        assert!(pool.scoped_run(0, 8, |i| inputs[i]).is_empty());
        assert_eq!(pool.scoped_run(3, 1, |i| i), vec![0, 1, 2]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // wall-clock sleeps observe nothing under Miri's scheduler
    fn scoped_run_is_concurrent() {
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        let pool = ThreadPool::new(8);
        pool.scoped_run(16, 8, |_| {
            let live = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(live, Ordering::SeqCst);
            thread::sleep(std::time::Duration::from_millis(20));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) > 1, "no observed concurrency");
    }

    #[test]
    fn scoped_run_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scoped_run(8, 4, |i| {
                if i == 5 {
                    panic!("scoped boom");
                }
                i
            })
        }));
        assert!(r.is_err(), "worker panic not propagated");
        // the workers caught the panic — the pool still works afterwards
        assert_eq!(pool.scoped_run(4, 4, |i| i * 2), vec![0, 2, 4, 6]);
    }

    #[test]
    fn try_scoped_run_isolates_panics_per_index() {
        // satellite regression test: a panicking evaluation poisons ONLY
        // its own index — siblings complete, scratch slots stay live, and
        // the pool remains fully usable afterwards
        let pool = ThreadPool::new(4);
        let mut slots: Vec<usize> = vec![0; 4];
        let out = pool.try_scoped_run_slots(16, &mut slots, |hits, i| {
            *hits += 1;
            if i % 5 == 0 {
                panic!("poisoned index {i}");
            }
            i * 10
        });
        assert_eq!(out.len(), 16);
        for (i, r) in out.iter().enumerate() {
            if i % 5 == 0 {
                assert!(r.is_err(), "index {i} should have panicked");
            } else {
                match r {
                    Ok(v) => assert_eq!(*v, i * 10),
                    Err(_) => panic!("index {i} unexpectedly poisoned"),
                }
            }
        }
        // every index ran exactly once, panicking ones included
        assert_eq!(slots.iter().sum::<usize>(), 16);
        // the same pool + slots serve the next batch (workers survived)
        let again = pool.scoped_run_slots(4, &mut slots, |_, i| i);
        assert_eq!(again, vec![0, 1, 2, 3]);
        // the serial (single-slot) fast path isolates identically
        let mut one = vec![0usize];
        let out = pool.try_scoped_run_slots(3, &mut one, |_, i| {
            if i == 1 {
                panic!("serial boom");
            }
            i
        });
        assert!(out[0].is_ok() && out[1].is_err() && out[2].is_ok());
    }

    #[test]
    fn scoped_run_with_threads_per_worker_scratch() {
        let pool = ThreadPool::new(4);
        let mut inits = 0usize;
        let mut scratch: Vec<Vec<usize>> = Vec::new();
        // each worker logs the indices it processed into ITS slot
        let out = pool.scoped_run_with(
            64,
            4,
            &mut scratch,
            || {
                inits += 1;
                Vec::new()
            },
            |log: &mut Vec<usize>, i| {
                log.push(i);
                i * 2
            },
        );
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(inits, 4, "one scratch slot per participating worker");
        assert_eq!(scratch.len(), 4);
        // every index was processed by exactly one worker
        let mut all: Vec<usize> = scratch.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());

        // a second call REUSES the scratch (init not called again) and
        // keeps appending to the same worker-local state
        let before: usize = scratch.iter().map(|s| s.len()).sum();
        pool.scoped_run_with(10, 4, &mut scratch, || unreachable!("scratch is warm"), |log, i| {
            log.push(i);
        });
        let after: usize = scratch.iter().map(|s| s.len()).sum();
        assert_eq!(after, before + 10);

        // the single-worker fast path shares slot 0
        pool.scoped_run_with(3, 1, &mut scratch, Vec::new, |log, i| log.push(100 + i));
        assert!(scratch[0].ends_with(&[100, 101, 102]));
    }

    #[test]
    fn scoped_run_slots_respects_caller_sized_slots() {
        let pool = ThreadPool::new(8);
        // the caller sizes the slot pool once; worker count is capped by it
        let mut slots: Vec<Vec<usize>> = vec![Vec::new(); 3];
        let out = pool.scoped_run_slots(32, &mut slots, |log, i| {
            log.push(i);
            i + 1
        });
        assert_eq!(out, (1..=32).collect::<Vec<_>>());
        assert_eq!(slots.len(), 3, "slot pool must not grow");
        let mut all: Vec<usize> = slots.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());

        // single slot → serial path on slot 0
        let mut one = vec![0usize];
        let out = pool.scoped_run_slots(4, &mut one, |acc, i| {
            *acc += i;
            i
        });
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(one[0], 6, "slot 0 accumulated 0+1+2+3");
    }

    #[test]
    fn concurrent_scoped_runs_on_disjoint_slot_ranges_are_race_free() {
        // Two scoped_run_slots calls racing on the SAME pool, each given
        // a disjoint half of one caller-owned slot buffer. Miri (and
        // TSan, in the scheduled CI job) verify the SendPtr argument:
        // disjoint slot ranges from distinct calls never alias.
        let pool = ThreadPool::new(4);
        let mut slots: Vec<u64> = vec![0; 4];
        let (lo, hi) = slots.split_at_mut(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                let out = pool.scoped_run_slots(8, lo, |acc, i| {
                    *acc += 1;
                    i as u64
                });
                assert_eq!(out, (0..8).collect::<Vec<u64>>());
            });
            s.spawn(|| {
                let out = pool.scoped_run_slots(8, hi, |acc, i| {
                    *acc += 1;
                    2 * i as u64
                });
                assert_eq!(out, (0..8).map(|i| 2 * i).collect::<Vec<u64>>());
            });
        });
        // every one of the 16 indices incremented exactly one slot
        assert_eq!(slots.iter().sum::<u64>(), 16);
    }

    #[test]
    #[should_panic(expected = "at least one scratch slot")]
    fn scoped_run_slots_rejects_empty_slot_pool() {
        let pool = ThreadPool::new(2);
        let mut slots: Vec<()> = Vec::new();
        pool.scoped_run_slots(1, &mut slots, |_, i| i);
    }

    #[test]
    fn pool_executes_all() {
        static DONE: AtomicUsize = AtomicUsize::new(0);
        {
            let pool = ThreadPool::new(4);
            for _ in 0..50 {
                pool.execute(|| {
                    DONE.fetch_add(1, Ordering::SeqCst);
                });
            }
        }
        assert_eq!(DONE.load(Ordering::SeqCst), 50);
    }
}

//! Fixed-size thread pool (tokio is unavailable offline).
//!
//! Catla's Project Runner and the benchmark harness evaluate independent
//! cluster jobs concurrently; `map_parallel` preserves input order and
//! propagates panics.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple work-stealing-free pool: one shared queue, N workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("catla-pool-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool worker hung up");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over `items` on `threads` workers; results keep input order.
/// Panics in workers are re-raised here.
pub fn map_parallel<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }
    let f = Arc::new(f);
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<R>)>();
    {
        let pool = ThreadPool::new(threads);
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            pool.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
    } // pool drop joins workers
    drop(tx);
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx.iter() {
        match r {
            Ok(v) => slots[i] = Some(v),
            Err(p) => std::panic::resume_unwind(p),
        }
    }
    slots.into_iter().map(|s| s.expect("missing result")).collect()
}

/// Default parallelism for host-side work.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let out = map_parallel((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let out: Vec<i32> = map_parallel(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn runs_concurrently() {
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        map_parallel((0..16).collect::<Vec<_>>(), 8, |_| {
            let live = LIVE.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(live, Ordering::SeqCst);
            thread::sleep(std::time::Duration::from_millis(20));
            LIVE.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) > 1, "no observed concurrency");
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panic_propagates() {
        map_parallel(vec![1, 2, 3], 2, |x| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn pool_executes_all() {
        static DONE: AtomicUsize = AtomicUsize::new(0);
        {
            let pool = ThreadPool::new(4);
            for _ in 0..50 {
                pool.execute(|| {
                    DONE.fetch_add(1, Ordering::SeqCst);
                });
            }
        }
        assert_eq!(DONE.load(Ordering::SeqCst), 50);
    }
}

//! Foundation substrates the offline image forces us to own: RNG, JSON,
//! CSV, CLI parsing, a thread pool, dense linear algebra, a bench harness
//! and a property-testing driver. See DESIGN.md §2 (environment
//! substitutions) for the rationale of each.

pub mod bench;
pub mod cli;
pub mod crashpoint;
pub mod csv;
pub mod durable;
pub mod fingerprint;
pub mod json;
pub mod linalg;
pub mod ord;
pub mod pool;
pub mod quickcheck;
pub mod rng;

//! Total-order adapters for floats.

use std::cmp::Ordering;

/// `f64` ordered by IEEE-754 totalOrder ([`f64::total_cmp`]): a real
/// `Ord` for heap/tree keys. Keys equal under this order are
/// BIT-IDENTICAL (totalOrder distinguishes -0.0 from 0.0 and NaN
/// payloads), which is what lets heap-based structures reproduce
/// sort-based selections exactly — the simulator's running straggler
/// median and the YARN allocation index both lean on that guarantee.
#[derive(Clone, Copy, Debug)]
pub struct TotalF64(pub f64);

impl PartialEq for TotalF64 {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}
impl Eq for TotalF64 {}
impl Ord for TotalF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}
impl PartialOrd for TotalF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_order_sorts_like_total_cmp() {
        let mut xs = [3.0, -0.0, 0.0, f64::NAN, -1.5, f64::INFINITY, 3.0];
        let mut by_wrapper: Vec<TotalF64> = xs.iter().copied().map(TotalF64).collect();
        by_wrapper.sort();
        xs.sort_by(|a, b| a.total_cmp(b));
        for (w, x) in by_wrapper.iter().zip(&xs) {
            assert_eq!(w.0.to_bits(), x.to_bits());
        }
    }

    #[test]
    fn equality_means_bit_identity() {
        assert_ne!(TotalF64(-0.0), TotalF64(0.0));
        assert_eq!(TotalF64(2.5), TotalF64(2.5));
        assert!(TotalF64(-0.0) < TotalF64(0.0));
    }
}

//! Bit-exact FNV-1a fingerprints shared by grid dedup and the serve
//! daemon's simulation memo-cache.
//!
//! One hashing discipline everywhere: every `f64` is hashed by its raw
//! IEEE-754 bits (`to_bits`, little-endian bytes), so two values share a
//! fingerprint iff they are bit-identical — `0.0` and `-0.0` differ, any
//! two NaN payloads differ, and no formatting or rounding is involved.
//! [`config_value_key`] is byte-for-byte the key `GridSearch` has always
//! computed for its constraint dedup (extracted here so the memo-cache
//! reuses the same hashing); [`eval_fingerprint`] extends it over the
//! full simulation input — cluster spec, noise model, workload profile,
//! decoded config values and seed — which is exactly the argument tuple
//! of the pure `simulate_runtime`, making a fingerprint hit sufficient
//! for serving the cached runtime without touching the DES.
//!
//! All keys are 64-bit, so distinct inputs collide with ~2^-64 odds —
//! the same accepted risk the grid dedup key carries.

use crate::config::params::HadoopConfig;
use crate::hadoop::ClusterSpec;
use crate::workloads::WorkloadSpec;

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) -> &mut Fnv1a {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        self
    }

    pub fn write_u64(&mut self, v: u64) -> &mut Fnv1a {
        self.write(&v.to_le_bytes())
    }

    /// Hash the raw IEEE-754 bits (bit-exact: -0.0 != 0.0, NaN payloads
    /// distinct).
    pub fn write_f64_bits(&mut self, v: f64) -> &mut Fnv1a {
        self.write(&v.to_bits().to_le_bytes())
    }

    /// Hash a string with a terminator byte, so `("ab", "c")` and
    /// `("a", "bc")` never collide by concatenation.
    pub fn write_str(&mut self, s: &str) -> &mut Fnv1a {
        self.write(s.as_bytes()).write(&[0xff])
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Bit-exact dedup key over a decoded config's value bits — the exact
/// key `GridSearch` computes for constraint-collapsed grid points and
/// resume replay (two configs share a key iff every value is
/// bit-identical).
pub fn config_value_key(values: &[f64]) -> u64 {
    let mut h = Fnv1a::new();
    for v in values {
        h.write_f64_bits(*v);
    }
    h.finish()
}

/// [`config_value_key`] plus registry identity: the parameter names are
/// hashed before the value bits, so two configs laid out on different
/// registries (e.g. a spec-declared extra dimension) never share a
/// fingerprint even when their value vectors coincide.
pub fn config_fingerprint(cfg: &HadoopConfig) -> u64 {
    let mut h = Fnv1a::new();
    for d in cfg.registry().defs() {
        h.write_str(&d.name);
    }
    for v in &cfg.values {
        h.write_f64_bits(*v);
    }
    h.finish()
}

fn write_cluster(h: &mut Fnv1a, cl: &ClusterSpec) {
    h.write_u64(cl.nodes as u64)
        .write_u64(cl.racks as u64)
        .write_u64(cl.mem_per_node_mb as u64)
        .write_u64(cl.vcores_per_node as u64)
        .write_f64_bits(cl.disk_mbps)
        .write_f64_bits(cl.net_mbps)
        .write_u64(cl.replication as u64)
        .write_f64_bits(cl.task_overhead_s)
        .write_f64_bits(cl.am_overhead_s)
        .write_f64_bits(cl.locality)
        .write_f64_bits(cl.noise.sigma)
        .write_f64_bits(cl.noise.node_sigma)
        .write_f64_bits(cl.noise.straggler_prob)
        .write_f64_bits(cl.noise.straggler_mult.0)
        .write_f64_bits(cl.noise.straggler_mult.1)
        .write_f64_bits(cl.noise.failure_prob)
        .write_u64(cl.noise.max_attempts as u64)
        .write_f64_bits(cl.fault.mttf_s)
        .write_f64_bits(cl.fault.recovery_s)
        .write_u64(cl.fault.max_concurrent as u64)
        .write_u64(cl.speculative as u64);
    // cl.seed is deliberately NOT hashed: the per-run simulation seed is
    // a separate fingerprint component (eval_fingerprint's `seed`), and
    // two clusters differing only in base seed produce identical runs
    // when handed the same per-run seed.
}

fn write_workload(h: &mut Fnv1a, wl: &WorkloadSpec) {
    h.write_str(&wl.name)
        .write_f64_bits(wl.input_mb)
        .write_f64_bits(wl.map_selectivity)
        .write_f64_bits(wl.cpu_per_mb_map)
        .write_f64_bits(wl.cpu_per_mb_red)
        .write_f64_bits(wl.compress_ratio)
        .write_f64_bits(wl.output_selectivity)
        .write_f64_bits(wl.record_kb)
        .write_f64_bits(wl.key_skew);
}

/// Fingerprint of one simulation run: the bit-exact
/// (cluster, workload, config-values, seed) tuple —
/// `simulate_runtime(spec, wl, cfg, seed)` is a pure function of exactly
/// these inputs, so equal fingerprints (collision odds aside) mean
/// equal runtimes and a memo-cache hit is sound.
pub fn eval_fingerprint(cl: &ClusterSpec, wl: &WorkloadSpec, cfg: &HadoopConfig, seed: u64) -> u64 {
    let mut h = Fnv1a::new();
    write_cluster(&mut h, cl);
    write_workload(&mut h, wl);
    for d in cfg.registry().defs() {
        h.write_str(&d.name);
    }
    for v in &cfg.values {
        h.write_f64_bits(*v);
    }
    h.write_u64(seed);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::wordcount;

    #[test]
    fn config_value_key_matches_the_historical_grid_key() {
        // the inlined original: FNV-1a over value bits, le bytes
        fn original(values: &[f64]) -> u64 {
            let mut h = FNV_OFFSET;
            for v in values {
                for b in v.to_bits().to_le_bytes() {
                    h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
                }
            }
            h
        }
        for vals in [
            vec![],
            vec![0.0],
            vec![1.5, -3.25, 1e300],
            vec![f64::NAN, f64::INFINITY, -0.0],
        ] {
            assert_eq!(config_value_key(&vals), original(&vals));
        }
    }

    #[test]
    fn keys_are_stable_across_runs() {
        // pinned values: any change to the hashing discipline (order,
        // byte layout, constants) is a cache/dedup-breaking change and
        // must show up here
        assert_eq!(config_value_key(&[]), FNV_OFFSET);
        assert_eq!(config_value_key(&[0.0]), 0xa8c7_f832_281a_39c5);
        assert_eq!(
            config_value_key(&[1.0, 2.0]),
            {
                let mut h = Fnv1a::new();
                h.write_u64(1.0f64.to_bits()).write_u64(2.0f64.to_bits());
                h.finish()
            },
            "f64 bit hashing must equal hashing the bits as u64 le bytes"
        );
        let k1 = config_value_key(&[4.0, 256.0, 0.66]);
        let k2 = config_value_key(&[4.0, 256.0, 0.66]);
        assert_eq!(k1, k2);
    }

    #[test]
    fn edge_values_stay_distinct() {
        // -0.0 vs 0.0: equal as f64, different bits, different keys
        assert_ne!(config_value_key(&[0.0]), config_value_key(&[-0.0]));
        // NaN vs any number, and NaN payloads
        assert_ne!(config_value_key(&[f64::NAN]), config_value_key(&[0.0]));
        let quiet = f64::NAN;
        let payload = f64::from_bits(quiet.to_bits() ^ 1);
        assert!(payload.is_nan());
        assert_ne!(
            config_value_key(&[quiet]),
            config_value_key(&[payload]),
            "distinct NaN payloads must not share a key"
        );
        // order matters
        assert_ne!(config_value_key(&[1.0, 2.0]), config_value_key(&[2.0, 1.0]));
    }

    #[test]
    fn config_fingerprint_separates_registries() {
        let base = HadoopConfig::default();
        let spec = crate::config::spec::TuningSpec::parse(
            "param x.shuffle.buffer.kb int 32 4096\n",
        )
        .unwrap();
        let extra = HadoopConfig::for_registry(spec.registry.clone());
        // same leading value bits, different registries
        assert_ne!(config_fingerprint(&base), config_fingerprint(&extra));
        // and stable for equal configs
        assert_eq!(config_fingerprint(&base), config_fingerprint(&HadoopConfig::default()));
    }

    #[test]
    fn eval_fingerprint_tracks_every_component() {
        let cl = ClusterSpec::default();
        let wl = wordcount(2048.0);
        let cfg = HadoopConfig::default();
        let k = eval_fingerprint(&cl, &wl, &cfg, 7);
        assert_eq!(k, eval_fingerprint(&cl, &wl, &cfg, 7), "not deterministic");

        // seed
        assert_ne!(k, eval_fingerprint(&cl, &wl, &cfg, 8));
        // workload
        assert_ne!(k, eval_fingerprint(&cl, &wl.clone().with_input_mb(1024.0), &cfg, 7));
        // cluster (noise matters: differing sigma can never share a hit)
        let mut noisy = cl.clone();
        noisy.noise.sigma += 0.01;
        assert_ne!(k, eval_fingerprint(&noisy, &wl, &cfg, 7));
        // fault model: a flaky cluster must never share a hit with a
        // healthy one (mttf), and neither may recovery/concurrency
        // variants of the same failure rate
        let mut flaky = cl.clone();
        flaky.fault.mttf_s = 600.0;
        let kf = eval_fingerprint(&flaky, &wl, &cfg, 7);
        assert_ne!(k, kf);
        let mut slow_recovery = flaky.clone();
        slow_recovery.fault.recovery_s += 1.0;
        assert_ne!(kf, eval_fingerprint(&slow_recovery, &wl, &cfg, 7));
        let mut wide = flaky.clone();
        wide.fault.max_concurrent += 1;
        assert_ne!(kf, eval_fingerprint(&wide, &wl, &cfg, 7));
        // config values
        let mut cfg2 = cfg.clone();
        cfg2.set(crate::config::params::P_REDUCES, 3.0);
        assert_ne!(k, eval_fingerprint(&cl, &wl, &cfg2, 7));

        // the cluster BASE seed is not part of the key: per-run seeds
        // are, so two projects that differ only in sim.seed
        // still share cache entries for the same per-run seed (two
        // daemons' projects differing only in sim.seed still dedup)
        let mut reseeded = cl.clone();
        reseeded.seed = 12345;
        assert_eq!(k, eval_fingerprint(&reseeded, &wl, &cfg, 7));
    }
}

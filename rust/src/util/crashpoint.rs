//! Deterministic crash injection: named points threaded through the
//! persistence layer where a `kill -9` would be most damaging.
//!
//! Production cost when unarmed is a single relaxed [`AtomicBool`] load
//! per point — no allocation, no branch beyond the early return. Arming
//! happens exactly once, from `main.rs` (the hidden `--crash-at <point>`
//! flag or the `CATLA_CRASH_AT` env hook — both live in the CLI entry,
//! which owns argv/env under the detlint ambient-entropy rule), before
//! any worker thread starts.
//!
//! A hit calls [`std::process::abort`]: no destructors, no buffered-I/O
//! flushing, no atexit — the closest in-process stand-in for SIGKILL.
//! Writes already handed to the OS survive (they are in the page cache);
//! anything user-space-buffered is lost, exactly like a torn crash.
//!
//! The registry below is the single source of truth: `--crash-at`
//! validates against it and the crash-matrix test
//! (`rust/tests/crash_matrix.rs`) iterates it, so an unregistered or
//! unreachable point fails CI rather than rotting.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Every registered crash point, in rough persistence-pipeline order.
///
/// * `journal.*` fire around the per-slice checkpoint append
///   (`ServeSession::checkpoint`); `mid-append` aborts with only the
///   first half of the record durable, manufacturing a genuinely torn
///   tail.
/// * `finalize.*` fire between the finalize steps (final log → `fin`
///   journal record → summary row → journal removal);
///   `fin.mid-append` tears the `fin` record itself.
/// * `summary.mid-append` tears the summary row itself.
/// * `atomic.*` fire inside [`crate::util::durable::atomic_write`],
///   between tmp-sync, rename and directory-sync.
pub const POINTS: &[&str] = &[
    "journal.before-append",
    "journal.mid-append",
    "journal.after-append",
    "finalize.before-log",
    "finalize.before-fin",
    "fin.mid-append",
    "finalize.before-summary",
    "summary.mid-append",
    "finalize.before-cleanup",
    "atomic.after-tmp",
    "atomic.after-rename",
];

/// Fast-path switch: false until [`arm`] succeeds.
static ON: AtomicBool = AtomicBool::new(false);
/// Index into [`POINTS`] of the armed point (valid only when `ON`).
static ARMED: AtomicUsize = AtomicUsize::new(usize::MAX);

/// Arm one crash point by name. Called once from the CLI entry before
/// any session work starts; unknown names error so a typo in
/// `--crash-at` fails loudly instead of silently never firing.
pub fn arm(point: &str) -> Result<(), String> {
    let idx = POINTS
        .iter()
        .position(|p| *p == point)
        .ok_or_else(|| format!("unknown crash point {point:?} (known: {})", POINTS.join(", ")))?;
    ARMED.store(idx, Ordering::Relaxed);
    ON.store(true, Ordering::Relaxed);
    Ok(())
}

/// Is `point` the armed one? (Zero-cost `false` when nothing is armed.)
pub fn armed_at(point: &str) -> bool {
    if !ON.load(Ordering::Relaxed) {
        return false;
    }
    POINTS.get(ARMED.load(Ordering::Relaxed)).copied() == Some(point)
}

/// Abort the process if `point` is armed. The stderr line is written and
/// flushed first so the matrix test can assert which point fired.
pub fn crash_if(point: &str) {
    if armed_at(point) {
        crash_now(point);
    }
}

/// Unconditional abort with the diagnostic line — callers that already
/// checked [`armed_at`] (to set up a torn half-write first) end here.
pub fn crash_now(point: &str) -> ! {
    use std::io::Write;
    let mut err = std::io::stderr();
    let _ = writeln!(err, "catla: crash point {point:?} hit — aborting");
    let _ = err.flush();
    std::process::abort();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_nonempty() {
        let mut seen = std::collections::BTreeSet::new();
        for p in POINTS {
            assert!(!p.is_empty());
            assert!(seen.insert(*p), "duplicate crash point {p:?}");
        }
    }

    #[test]
    fn unknown_point_is_rejected_and_unarmed_is_inert() {
        assert!(arm("no.such.point").is_err());
        // arming never happened in this process, so every probe is false
        // and crash_if returns (the test would abort otherwise)
        assert!(!armed_at("journal.before-append"));
        crash_if("journal.before-append");
    }
}

//! Minimal JSON value model, writer and parser.
//!
//! `serde`/`serde_json` are unavailable offline; the simulator's
//! job-history logs (what a real Catla downloads from the YARN history
//! server) are JSON, and the metrics parser reads them back, so the
//! round-trip is a first-class substrate.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emitted logs are
/// byte-deterministic for a given job — tests rely on that.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set() on non-object Json");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["job", "counters", "SPILLED_RECORDS"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    #[allow(clippy::float_cmp)] // fract() == 0.0 is the exact integer-rendering test JSON needs
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization — `to_string()` comes from this impl.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":"x\ny"}],"c":{"d":null,"e":3.25}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.to_string(), src.replace(" ", ""));
        assert_eq!(v.at(&["c", "e"]).unwrap().as_f64(), Some(3.25));
    }

    #[test]
    fn escapes_roundtrip() {
        let mut o = Json::obj();
        o.set("k", Json::from("line1\nline2\t\"q\"\\"));
        let back = parse(&o.to_string()).unwrap();
        assert_eq!(back.get("k").unwrap().as_str().unwrap(), "line1\nline2\t\"q\"\\");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "tru", "1 2", "{\"a\":}"] {
            assert!(parse(s).is_err(), "accepted {s:?}");
        }
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }
}

//! CSV writer/reader for Catla's `/history` summaries.
//!
//! The paper's workflow exports job metrics as `*.csv` for visualization
//! in Minitab/MATLAB; we keep the format dumb and round-trippable.

/// In-memory CSV table with a header row.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Convenience: push a row of display-able values.
    pub fn push<T: std::fmt::Display>(&mut self, vals: &[T]) {
        self.push_row(vals.iter().map(|v| v.to_string()).collect());
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Column as f64 (non-numeric cells become NaN).
    pub fn col_f64(&self, name: &str) -> Option<Vec<f64>> {
        let i = self.col_index(name)?;
        Some(
            self.rows
                .iter()
                .map(|r| r[i].parse::<f64>().unwrap_or(f64::NAN))
                .collect(),
        )
    }

    pub fn parse(input: &str) -> Result<Csv, String> {
        let mut lines = input.lines();
        let header = match lines.next() {
            Some(l) => parse_record(l)?,
            None => return Err("empty csv".into()),
        };
        let mut rows = Vec::new();
        for (no, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let rec = parse_record(line)?;
            if rec.len() != header.len() {
                return Err(format!(
                    "line {}: {} fields, expected {}",
                    no + 2,
                    rec.len(),
                    header.len()
                ));
            }
            rows.push(rec);
        }
        Ok(Csv { header, rows })
    }

    /// Durable save: atomic replace via [`crate::util::durable`] so a
    /// crash mid-save leaves the previous file intact, never a torn one.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::durable::atomic_write(path, self.to_string().as_bytes())
    }

    pub fn load(path: &std::path::Path) -> Result<Csv, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Csv::parse(&text)
    }

    /// Crash-tolerant load: every writer newline-terminates each row, so
    /// a file whose final line lacks `\n` was cut mid-append — drop that
    /// partial line and report it as `Some(warning)`. Anything wrong in
    /// the surviving prefix (ragged interior row, bad quoting) is still a
    /// hard error: a torn *tail* is what crashes produce, a torn middle
    /// is corruption.
    pub fn load_tolerant(path: &std::path::Path) -> Result<(Csv, Option<String>), String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let mut warning = None;
        let clean = if !text.is_empty() && !text.ends_with('\n') {
            let keep = text.rfind('\n').map(|i| i + 1).unwrap_or(0);
            warning = Some(format!(
                "{}: dropped torn final line ({} bytes) — file was cut mid-write",
                path.display(),
                text.len() - keep
            ));
            &text[..keep]
        } else {
            text.as_str()
        };
        let csv = Csv::parse(clean)?;
        Ok((csv, warning))
    }

    /// Render a single row as one CSV line (with trailing newline) using
    /// the same quoting as `to_string()` — the unit the append-only
    /// summary/journal writers add per record.
    pub fn render_row(fields: &[String]) -> String {
        let mut out = String::new();
        write_record(fields, &mut out);
        out
    }

    /// The header rendered as one CSV line (with trailing newline).
    pub fn render_header(&self) -> String {
        Self::render_row(&self.header)
    }
}

/// RFC-4180-ish rendering — `to_string()` comes from this impl.
impl std::fmt::Display for Csv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        write_record(&self.header, &mut out);
        for r in &self.rows {
            write_record(r, &mut out);
        }
        f.write_str(&out)
    }
}

fn needs_quoting(field: &str) -> bool {
    field.contains(',') || field.contains('"') || field.contains('\n')
}

fn write_record(fields: &[String], out: &mut String) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(f) {
            out.push('"');
            out.push_str(&f.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(f);
        }
    }
    out.push('\n');
}

fn parse_record(line: &str) -> Result<Vec<String>, String> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        cur.push('"');
                        chars.next();
                    } else {
                        in_quotes = false;
                    }
                }
                c => cur.push(c),
            }
        } else {
            match c {
                ',' => {
                    fields.push(std::mem::take(&mut cur));
                }
                '"' if cur.is_empty() => in_quotes = true,
                c => cur.push(c),
            }
        }
    }
    if in_quotes {
        return Err("unterminated quote".into());
    }
    fields.push(cur);
    Ok(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut c = Csv::new(&["iter", "runtime_s", "config"]);
        c.push(&["1", "120.5", "r=4"]);
        c.push(&["2", "98.1", "r=8"]);
        let back = Csv::parse(&c.to_string()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn roundtrip_quoted() {
        let mut c = Csv::new(&["a", "b"]);
        c.push_row(vec!["x,y".into(), "say \"hi\"".into()]);
        let back = Csv::parse(&c.to_string()).unwrap();
        assert_eq!(back.rows[0][0], "x,y");
        assert_eq!(back.rows[0][1], "say \"hi\"");
    }

    #[test]
    fn col_f64_extraction() {
        let mut c = Csv::new(&["k", "v"]);
        c.push(&["a", "1.5"]);
        c.push(&["b", "2.5"]);
        assert_eq!(c.col_f64("v").unwrap(), vec![1.5, 2.5]);
        assert!(c.col_f64("missing").is_none());
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(Csv::parse("a,b\n1\n").is_err());
    }

    #[test]
    #[should_panic]
    fn push_checks_width() {
        let mut c = Csv::new(&["a", "b"]);
        c.push(&["only-one"]);
    }

    #[test]
    fn render_row_matches_display() {
        let mut c = Csv::new(&["a", "b"]);
        c.push_row(vec!["x,y".into(), "z".into()]);
        let rendered = c.render_header() + &Csv::render_row(&c.rows[0]);
        assert_eq!(rendered, c.to_string());
    }

    #[test]
    fn load_tolerant_drops_only_a_torn_tail() {
        let dir = std::env::temp_dir().join(format!("catla-csv-tolerant-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.csv");

        // clean file → no warning
        std::fs::write(&path, "a,b\n1,2\n").unwrap();
        let (csv, warn) = Csv::load_tolerant(&path).unwrap();
        assert_eq!(csv.rows.len(), 1);
        assert!(warn.is_none());

        // torn final line → dropped with a warning, prefix intact
        std::fs::write(&path, "a,b\n1,2\n3,").unwrap();
        let (csv, warn) = Csv::load_tolerant(&path).unwrap();
        assert_eq!(csv.rows, vec![vec!["1".to_string(), "2".to_string()]]);
        assert!(warn.unwrap().contains("torn final line"));

        // torn-only file → hard "empty csv" error, not a panic
        std::fs::write(&path, "a,").unwrap();
        assert!(Csv::load_tolerant(&path).is_err());

        // ragged interior row → still a hard error even with a clean tail
        std::fs::write(&path, "a,b\n1\n2,3\n").unwrap();
        assert!(Csv::load_tolerant(&path).is_err());

        std::fs::remove_dir_all(&dir).unwrap();
    }
}

//! Deterministic, seedable PRNG (xoshiro256** seeded via SplitMix64).
//!
//! The image is offline and the `rand` crate is unavailable, so the
//! simulator carries its own generator. Determinism under a fixed seed is
//! a hard requirement: every experiment in EXPERIMENTS.md records its seed
//! and must replay bit-identically.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the simulator's workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (used to give every task its own
    /// noise stream so scheduling order never perturbs sampled durations).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free enough for simulation purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Log-normal with log-space mean `mu` and std `sigma`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 0.3) > 0.0);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent_of_call_order() {
        let mut root1 = Rng::new(5);
        let mut root2 = Rng::new(5);
        let mut a1 = root1.fork(100);
        let mut b1 = root1.fork(200);
        let mut a2 = root2.fork(100);
        let mut b2 = root2.fork(200);
        // draw in different order; forked streams must not interleave
        let xa1: Vec<u64> = (0..8).map(|_| a1.next_u64()).collect();
        let xb1: Vec<u64> = (0..8).map(|_| b1.next_u64()).collect();
        let xb2: Vec<u64> = (0..8).map(|_| b2.next_u64()).collect();
        let xa2: Vec<u64> = (0..8).map(|_| a2.next_u64()).collect();
        assert_eq!(xa1, xa2);
        assert_eq!(xb1, xb2);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        let s = r.sample_indices(20, 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
    }
}

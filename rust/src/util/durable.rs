//! Crash-consistent file I/O: atomic whole-file replacement and a
//! CRC32-trailered append-only record log.
//!
//! Every durable artifact Catla writes goes through one of two shapes:
//!
//! * **Atomic replace** ([`atomic_write`]): write a hidden tmp sibling,
//!   fsync it, rename over the target, fsync the directory. A reader
//!   (or a post-crash restart) sees either the old bytes or the new
//!   bytes, never a torn mix — rename within one directory is atomic on
//!   every filesystem we care about.
//! * **Append-only records** ([`append_framed`] / [`load_records`]):
//!   one record per line, `payload crc32=xxxxxxxx`, O_APPEND + fdatasync
//!   per append. A crash mid-append leaves a *torn tail* — a final line
//!   with a missing newline or a bad trailer — which recovery detects
//!   and drops, replaying the clean prefix. A bad record *followed by a
//!   valid one* cannot be produced by any crash of an append-only
//!   writer, so it is classified as mid-file corruption and surfaced as
//!   a hard error instead of being silently skipped.
//!
//! The [`crate::util::crashpoint`] hooks inside both shapes let the
//! crash-matrix test abort this process at every window (tmp written
//! but not renamed, half a record appended, …) and prove recovery from
//! each one.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::{Path, PathBuf};

use crate::util::crashpoint;

/// IEEE CRC-32 (the zlib/PNG polynomial), table generated at compile
/// time — the offline image has no checksum crate and the journal only
/// needs torn-write detection, not cryptographic integrity.
const CRC_TABLE: [u32; 256] = crc32_table();

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// The tmp sibling `atomic_write` stages into: hidden (leading dot) so
/// `*.csv`-style globs over a history directory never pick up a
/// half-written file, deterministic so a crashed leftover is simply
/// overwritten by the next write (and removable by `catla fsck`).
pub fn tmp_sibling(path: &Path) -> PathBuf {
    let name = path.file_name().map(|n| n.to_string_lossy()).unwrap_or_default();
    path.with_file_name(format!(".{name}.tmp"))
}

/// Fsync a directory so a just-renamed entry survives power loss. Best
/// effort off the happy path: some filesystems refuse O_RDONLY dir
/// syncs — the rename itself is still atomic, we only lose the
/// directory-entry durability guarantee there.
pub fn fsync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Atomically replace `path` with `bytes`: tmp sibling → fsync → rename
/// → directory fsync. Creates parent directories as needed.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let parent = path.parent().filter(|p| !p.as_os_str().is_empty());
    if let Some(dir) = parent {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = tmp_sibling(path);
    {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    crashpoint::crash_if("atomic.after-tmp");
    std::fs::rename(&tmp, path)?;
    crashpoint::crash_if("atomic.after-rename");
    if let Some(dir) = parent {
        fsync_dir(dir);
    }
    Ok(())
}

/// Append `bytes` to `path` (creating it if needed) with one O_APPEND
/// write + fdatasync. `mid_point` names the crash point that tears this
/// append in half: when armed, only the first half of `bytes` is made
/// durable before the abort — the torn-tail case recovery must handle.
pub fn append_bytes(path: &Path, bytes: &[u8], mid_point: &str) -> io::Result<()> {
    if let Some(dir) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = OpenOptions::new().create(true).append(true).open(path)?;
    if crashpoint::armed_at(mid_point) && bytes.len() > 1 {
        f.write_all(&bytes[..bytes.len() / 2])?;
        f.sync_data()?;
        crashpoint::crash_now(mid_point);
    }
    f.write_all(bytes)?;
    f.sync_data()?;
    Ok(())
}

/// Write `bytes` to a brand-new file (O_EXCL) and sync it. `Ok(true)`
/// when this call created the file, `Ok(false)` when it already existed
/// (bytes untouched) — the write-header-once primitive for shared
/// append-only CSVs: concurrent writers race on creation, exactly one
/// wins, and nobody ever rewrites an existing file's contents.
pub fn create_excl(path: &Path, bytes: &[u8]) -> io::Result<bool> {
    let parent = path.parent().filter(|p| !p.as_os_str().is_empty());
    if let Some(dir) = parent {
        std::fs::create_dir_all(dir)?;
    }
    match OpenOptions::new().write(true).create_new(true).open(path) {
        Ok(mut f) => {
            f.write_all(bytes)?;
            f.sync_all()?;
            if let Some(dir) = parent {
                fsync_dir(dir);
            }
            Ok(true)
        }
        Err(e) if e.kind() == io::ErrorKind::AlreadyExists => Ok(false),
        Err(e) => Err(e),
    }
}

const CRC_SEP: &str = " crc32=";

/// Frame `payload` as one CRC-trailered record line and append it
/// durably. `payload` must not contain a newline (the line is the
/// framing unit).
pub fn append_framed(path: &Path, payload: &str, mid_point: &str) -> io::Result<()> {
    debug_assert!(!payload.contains('\n'), "record payloads are single lines");
    let line = format!("{payload}{CRC_SEP}{:08x}\n", crc32(payload.as_bytes()));
    append_bytes(path, line.as_bytes(), mid_point)
}

/// A parsed record log: the clean-prefix payloads plus what (if
/// anything) trails them.
#[derive(Clone, Debug, Default)]
pub struct RecordLog {
    /// Payloads of the valid prefix, in append order.
    pub records: Vec<String>,
    /// Byte length of the valid prefix — truncate the file here before
    /// appending again after a torn crash.
    pub clean_len: u64,
    /// Bytes after the clean prefix that failed validation (0 = clean).
    /// Always a *suffix*: anything else is corruption and errors.
    pub torn_bytes: u64,
}

/// Validate one framed line; `Some(payload)` when the CRC trailer
/// matches.
fn parse_framed(line: &str) -> Option<&str> {
    let (payload, crc_hex) = line.rsplit_once(CRC_SEP)?;
    if crc_hex.len() != 8 {
        return None;
    }
    let crc = u32::from_str_radix(crc_hex, 16).ok()?;
    (crc == crc32(payload.as_bytes())).then_some(payload)
}

/// Load a CRC-trailered record log, classifying the tail.
///
/// * Clean file → all payloads, `torn_bytes == 0`.
/// * Torn tail (incomplete final line, or invalid trailing lines with
///   nothing valid after them) → the clean-prefix payloads plus
///   `torn_bytes > 0`; the caller decides whether to warn-and-truncate.
/// * A valid record *after* an invalid one → `Err`: an append-only
///   writer cannot produce that by crashing, so the file was edited or
///   the disk corrupted it — refusing to guess protects the
///   byte-identity contract.
pub fn load_records(path: &Path) -> Result<RecordLog, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    parse_records(&bytes).map_err(|line| {
        format!(
            "{}: record {line} has a valid CRC after an invalid record — mid-file corruption, \
             not a torn crash; refusing to resume (inspect or `catla fsck` the directory)",
            path.display()
        )
    })
}

/// Pure parse of [`load_records`] (unit-testable without a filesystem).
/// `Err(line_no)` = the 1-based line of the valid-after-invalid record.
pub fn parse_records(bytes: &[u8]) -> Result<RecordLog, usize> {
    let text = String::from_utf8_lossy(bytes);
    let mut log = RecordLog::default();
    let mut offset = 0usize; // byte offset of the current line start
    let mut bad_since: Option<usize> = None; // offset of first invalid line
    for (idx, line) in text.split_inclusive('\n').enumerate() {
        let complete = line.ends_with('\n');
        let valid = complete.then(|| parse_framed(line.trim_end_matches('\n'))).flatten();
        match (valid, bad_since) {
            (Some(payload), None) => {
                log.records.push(payload.to_string());
                offset += line.len();
                log.clean_len = offset as u64;
            }
            (Some(_), Some(_)) => return Err(idx + 1),
            (None, None) => {
                bad_since = Some(offset);
                offset += line.len();
            }
            (None, Some(_)) => offset += line.len(),
        }
    }
    log.torn_bytes = bytes.len() as u64 - log.clean_len;
    Ok(log)
}

/// Truncate a record log back to its clean prefix (post-torn-crash
/// repair, before appending resumes) and sync the result.
pub fn truncate_to(path: &Path, len: u64) -> io::Result<()> {
    let f = OpenOptions::new().write(true).open(path)?;
    f.set_len(len)?;
    f.sync_all()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("catla-durable-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn atomic_write_replaces_and_cleans_tmp() {
        let dir = tmp("atomic");
        let path = dir.join("out.csv");
        atomic_write(&path, b"one\n").unwrap();
        atomic_write(&path, b"two\n").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two\n");
        assert!(!tmp_sibling(&path).exists(), "tmp sibling left behind");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn framed_roundtrip_and_torn_tail_classification() {
        let dir = tmp("framed");
        let path = dir.join("log.journal");
        append_framed(&path, "alpha\t1", "x").unwrap();
        append_framed(&path, "beta\t2", "x").unwrap();
        let full = load_records(&path).unwrap();
        assert_eq!(full.records, vec!["alpha\t1", "beta\t2"]);
        assert_eq!(full.torn_bytes, 0);

        // torn at every byte boundary: the clean prefix is always the
        // records whose full lines survived, never a corrupt row
        let bytes = std::fs::read(&path).unwrap();
        let first_line_len = full.clean_len as usize
            - (bytes.len() - bytes.iter().position(|&b| b == b'\n').unwrap() - 1)
            - 1;
        for cut in 0..bytes.len() {
            let log = parse_records(&bytes[..cut]).unwrap();
            let expect = if cut >= bytes.len() {
                2
            } else if cut > first_line_len {
                1
            } else {
                0
            };
            assert_eq!(log.records.len(), expect, "cut at {cut}");
            assert_eq!(log.clean_len + log.torn_bytes, cut as u64);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn valid_after_invalid_is_corruption() {
        let dir = tmp("corrupt");
        let path = dir.join("log.journal");
        append_framed(&path, "alpha", "x").unwrap();
        // flip a byte in the first record, keeping the second intact
        append_framed(&path, "beta", "x").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        assert!(parse_records(&bytes).is_err(), "corruption classified as torn");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncate_to_clean_prefix_enables_reappend() {
        let dir = tmp("truncate");
        let path = dir.join("log.journal");
        append_framed(&path, "alpha", "x").unwrap();
        let clean = load_records(&path).unwrap().clean_len;
        append_bytes(&path, b"half-a-rec", "x").unwrap(); // torn tail
        let log = load_records(&path).unwrap();
        assert_eq!(log.records.len(), 1);
        assert!(log.torn_bytes > 0);
        truncate_to(&path, log.clean_len).unwrap();
        assert_eq!(clean, log.clean_len);
        append_framed(&path, "beta", "x").unwrap();
        assert_eq!(load_records(&path).unwrap().records, vec!["alpha", "beta"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

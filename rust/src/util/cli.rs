//! Tiny command-line parser (clap is unavailable offline).
//!
//! Grammar: `catla <tool> [--key value]... [--flag]... [positional]...`
//! mirroring the paper's `java -jar Catla.jar -tool task -dir task_wordcount`
//! invocation style (we accept both `-key v` and `--key v`).

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub tool: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--").or_else(|| a.strip_prefix('-')) {
                if name.is_empty() {
                    return Err("empty option name".into());
                }
                // value may be attached (--k=v) or the next token
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with('-') || n.parse::<f64>().is_ok())
                    .unwrap_or(false)
                {
                    out.options.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.tool.is_empty() {
                out.tool = a;
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{key}: cannot parse {s:?}")),
        }
    }

    pub fn opt_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        Ok(self.opt_parse(key)?.unwrap_or(default))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn require(&self, key: &str) -> Result<&str, String> {
        self.opt(key).ok_or_else(|| format!("missing required --{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn paper_style_invocation() {
        let a = parse("task -dir task_wordcount");
        assert_eq!(a.tool, "task");
        assert_eq!(a.opt("dir"), Some("task_wordcount"));
    }

    #[test]
    fn double_dash_and_equals() {
        let a = parse("tuning --optimizer=bobyqa --budget 50");
        assert_eq!(a.opt("optimizer"), Some("bobyqa"));
        assert_eq!(a.opt_parse::<u32>("budget").unwrap(), Some(50));
    }

    #[test]
    fn flags_without_values() {
        let a = parse("visualize --quiet --out x.csv");
        assert!(a.has_flag("quiet"));
        assert_eq!(a.opt("out"), Some("x.csv"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("tuning --seed -5");
        assert_eq!(a.opt("seed"), Some("-5"));
    }

    #[test]
    fn positional_collected() {
        let a = parse("task a b");
        assert_eq!(a.positional, vec!["a", "b"]);
    }

    #[test]
    fn parse_errors_surface() {
        let a = parse("tuning --budget notanumber");
        assert!(a.opt_parse::<u32>("budget").is_err());
    }
}

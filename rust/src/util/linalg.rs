//! Small dense linear algebra for the BOBYQA optimizer.
//!
//! Row-major `f64` matrices; LU solve with partial pivoting and a
//! symmetric-indefinite-tolerant fallback (the KKT systems of
//! minimum-Frobenius-norm quadratic model updates are symmetric but
//! indefinite, so plain Cholesky is not enough).

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    #[allow(clippy::float_cmp)] // exact-zero skip is a fast path; any other value must multiply
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    /// Solve `self * x = b` via LU with partial pivoting.
    /// Returns None if the matrix is numerically singular.
    #[allow(clippy::float_cmp)] // exact-zero elimination factor skips a row op; tolerance handled by the pivot test
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        assert_eq!(self.rows, self.cols, "solve needs square matrix");
        assert_eq!(b.len(), self.rows);
        let n = self.rows;
        let mut a = self.data.clone();
        let mut x: Vec<f64> = b.to_vec();
        let mut piv: Vec<usize> = (0..n).collect();

        for col in 0..n {
            // pivot
            let mut best = col;
            let mut best_abs = a[piv[col] * n + col].abs();
            for r in col + 1..n {
                let v = a[piv[r] * n + col].abs();
                if v > best_abs {
                    best = r;
                    best_abs = v;
                }
            }
            if best_abs < 1e-14 {
                return None;
            }
            piv.swap(col, best);
            let prow = piv[col];
            let pivval = a[prow * n + col];
            for r in col + 1..n {
                let row = piv[r];
                let factor = a[row * n + col] / pivval;
                if factor == 0.0 {
                    continue;
                }
                a[row * n + col] = 0.0;
                for c in col + 1..n {
                    a[row * n + c] -= factor * a[prow * n + c];
                }
                x[row] -= factor * x[prow];
            }
        }
        // back substitution
        let mut out = vec![0.0; n];
        for col in (0..n).rev() {
            let row = piv[col];
            let mut v = x[row];
            for c in col + 1..n {
                v -= a[row * n + c] * out[c];
            }
            out[col] = v / a[row * n + col];
        }
        Some(out)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_identity() {
        let m = Mat::eye(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(m.solve(&b).unwrap(), b);
    }

    #[test]
    fn solve_known_system() {
        let m = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = m.solve(&[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_requires_pivoting() {
        // zero on the diagonal forces a row swap
        let m = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = m.solve(&[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_returns_none() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(m.solve(&[1.0, 2.0]).is_none());
    }

    #[test]
    fn solve_random_roundtrip() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for n in [1usize, 2, 5, 9, 16] {
            let mut m = Mat::zeros(n, n);
            for v in m.data.iter_mut() {
                *v = rng.range_f64(-1.0, 1.0);
            }
            for i in 0..n {
                m[(i, i)] += 3.0; // keep well-conditioned
            }
            let x_true: Vec<f64> = (0..n).map(|i| i as f64 - 1.5).collect();
            let b = m.matvec(&x_true);
            let x = m.solve(&b).unwrap();
            for (a, b) in x.iter().zip(&x_true) {
                assert!((a - b).abs() < 1e-9, "n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn matmul_transpose() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let at = a.transpose();
        let g = at.matmul(&a); // gram matrix, 2x2
        assert_eq!(g.rows, 2);
        assert!((g[(0, 0)] - 35.0).abs() < 1e-12);
        assert!((g[(0, 1)] - 44.0).abs() < 1e-12);
        assert!((g[(1, 1)] - 56.0).abs() < 1e-12);
    }

    #[test]
    fn vector_helpers() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(sub(&[3.0, 4.0], &[1.0, 1.0]), vec![2.0, 3.0]);
    }
}

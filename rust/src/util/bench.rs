//! Micro/meso benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is `harness = false` and drives this:
//! warmup, fixed-duration sampling, and a stats row (mean/p50/p95/min) in
//! a markdown table, plus free-form experiment output (the paper's
//! figures are regenerated as CSV + ASCII charts by the bench mains).

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub throughput: Option<(f64, &'static str)>, // items/sec, unit label
}

impl BenchStats {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Collects rows and prints a table at the end.
pub struct Bench {
    pub rows: Vec<BenchStats>,
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            rows: Vec::new(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(2),
            min_samples: 10,
            max_samples: 5_000,
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        // honor a quick mode for CI-ish runs
        let mut b = Self::default();
        if std::env::var("CATLA_BENCH_QUICK").is_ok() {
            b.warmup = Duration::from_millis(50);
            b.measure = Duration::from_millis(300);
            b.min_samples = 3;
        }
        b
    }

    /// Time `f` repeatedly; `f` returns a value that is black-boxed.
    pub fn run<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) -> &BenchStats {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.measure || samples_ns.len() < self.min_samples)
            && samples_ns.len() < self.max_samples
        {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.total_cmp(b));
        let n = samples_ns.len();
        let stats = BenchStats {
            name: name.to_string(),
            samples: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p50_ns: samples_ns[n / 2],
            p95_ns: samples_ns[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: samples_ns[0],
            throughput: None,
        };
        self.rows.push(stats);
        self.rows.last().unwrap()
    }

    /// Like `run`, attaching an items/second throughput computed from the
    /// per-iteration item count.
    pub fn run_throughput<R, F: FnMut() -> R>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        unit: &'static str,
        f: F,
    ) -> &BenchStats {
        self.run(name, f);
        let row = self.rows.last_mut().unwrap();
        row.throughput = Some((items_per_iter / (row.mean_ns / 1e9), unit));
        self.rows.last().unwrap()
    }

    pub fn print_table(&self, title: &str) {
        println!("\n## {title}\n");
        println!("| benchmark | samples | mean | p50 | p95 | min | throughput |");
        println!("|---|---|---|---|---|---|---|");
        for r in &self.rows {
            let tp = r
                .throughput
                .map(|(v, u)| format!("{v:.1} {u}/s"))
                .unwrap_or_else(|| "-".into());
            println!(
                "| {} | {} | {} | {} | {} | {} | {} |",
                r.name,
                r.samples,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p95_ns),
                fmt_ns(r.min_ns),
                tp
            );
        }
        println!();
    }
}

/// Opaque value sink, preventing the optimizer from deleting benchmarked
/// work (std::hint::black_box is stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_samples: 3,
            max_samples: 100,
            rows: Vec::new(),
        };
        b.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        let r = &b.rows[0];
        assert!(r.samples >= 3);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn throughput_positive() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_samples: 3,
            max_samples: 50,
            rows: Vec::new(),
        };
        b.run_throughput("t", 100.0, "items", || 1 + 1);
        assert!(b.rows[0].throughput.unwrap().0 > 0.0);
    }
}

//! `catla` CLI — the rust analogue of the paper's
//! `java -jar Catla.jar -tool task -dir task_wordcount` interface,
//! plus the CatlaUI terminal charts.
//!
//! Tools:
//!   template   create a task/project/tuning project folder from templates
//!   task       run a single job (paper §II.B.2 Steps 1-5)
//!   project    run a job group from jobs.list
//!   tuning     run the Optimizer Runner on a tuning project
//!   aggregate  re-aggregate logs after an interrupted run (§II.C.4)
//!   fsck       validate (and --repair) a history directory after a crash
//!   visualize  terminal charts + gnuplot scripts from /history CSVs
//!   describe   show the (simulated) cluster a project targets

use std::path::{Path, PathBuf};

use catla::catla::{
    aggregate, create_scoped_template, create_template, visualize, History, OptimizerRunner,
    Project, ProjectKind, ProjectRunner, TaskRunner, TuningSettings,
};
use catla::hadoop::{Cluster, ClusterSpec, SimCluster};
use catla::optim::surrogate::NativeScorer;
use catla::runtime::{CostModelExec, Runtime};
use catla::util::cli::Args;

const USAGE: &str = "catla — MapReduce performance self-tuning (Chen 2019 reproduction)

USAGE: catla <tool> [options]

TOOLS
  template  --dir <folder> [--kind task|project|tuning] [--workload wordcount]
            [--workloads a,b,...] [--input-mb 2048]
                                      create a project folder from templates;
                                      --workloads writes a scoped tuning
                                      template (jobs.list + per-workload
                                      `workload { ... }` spec blocks)
  task      --dir <folder>            submit one job, download results+logs
  project   --dir <folder>            run every job in jobs.list
  tuning    --dir <folder> [--prescreen native|pjrt|off]
                                      run the Optimizer Runner
  tuning-group --dir <folder>         tune ONE merged config for jobs.list
                                      (workload blocks scope dims per job)
  sweep     --dir <folder> [--shard k/n] [--budget N]
                                      exhaustive grid sweep; --shard stripes
                                      the grid so n independent processes
                                      partition the sweep exactly
  resume    --dir <folder> [--budget N]  continue an interrupted tuning run
  replay    --dir <folder> [--jobs N]    replay an arrival trace (default vs tuned)
  workflow  --dir <folder> [--tune]   run jobs.list as a DAG (after= deps);
                                      --tune first tunes the merged scoped
                                      space minimizing the DAG makespan
  ui        --dir <folder>            terminal dashboard (CatlaUI view)
  aggregate --dir <folder>            re-aggregate logs from /history
  fsck      --dir <folder> [--repair] check a history directory for crash
                                      damage; --repair truncates torn
                                      tails, retires checkpoint journals
                                      (materializing pending work), and
                                      removes stray staging files
  visualize --dir <folder> [--gnuplot]  charts from history CSVs
  describe  --dir <folder>            show the cluster this project targets
  serve     [--threads N] [--cache-entries N] [--queue N]
                                      tuning-as-a-service daemon: multiplex
                                      many tuning sessions over one shared
                                      simulator pool + global memo-cache
                                      (line protocol on stdin/stdout:
                                      open/step/run/ask/tell/status/close/
                                      stats/shutdown)

Optimizers (tuning.properties `optimizer=`): grid random latin coordinate
hooke-jeeves nelder-mead annealing bobyqa";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn project_dir(args: &Args) -> Result<PathBuf, String> {
    Ok(PathBuf::from(args.require("dir")?))
}

fn open_cluster(project: &Project) -> SimCluster {
    SimCluster::new(ClusterSpec::from_env(&project.env))
}

/// Surface non-fatal spec diagnostics (the params.spec typo guard,
/// aggregated across the global section and every workload block) on
/// stderr before a tuning run starts.
fn print_spec_warnings(project: &Project) {
    if let Some(scoped) = &project.scoped {
        for w in &scoped.warnings {
            eprintln!("warning: {w}");
        }
    }
}

/// Parse a `--shard k/n` value.
fn parse_shard(s: &str) -> Result<(u64, u64), String> {
    let err = || format!("--shard {s:?}: expected k/n with 0 <= k < n (e.g. 0/4)");
    let (k, n) = s.split_once('/').ok_or_else(err)?;
    let k: u64 = k.trim().parse().map_err(|_| err())?;
    let n: u64 = n.trim().parse().map_err(|_| err())?;
    if n == 0 || k >= n {
        return Err(err());
    }
    Ok((k, n))
}

fn run(args: &Args) -> Result<(), String> {
    match args.tool.as_str() {
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        "template" => {
            let dir = project_dir(args)?;
            let input_mb: f64 = args.opt_parse_or("input-mb", 2048.0)?;
            if let Some(list) = args.opt("workloads") {
                // scoped multi-workload tuning template: jobs.list + a
                // params.spec with per-workload blocks from the suites'
                // attached tuning specs
                let names: Vec<&str> =
                    list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
                create_scoped_template(&dir, &names, input_mb)?;
                println!("created scoped Tuning project at {}", dir.display());
                println!("next: catla workflow --dir {} --tune", dir.display());
                return Ok(());
            }
            let kind = match args.opt_or("kind", "task").as_str() {
                "task" => ProjectKind::Task,
                "project" => ProjectKind::Project,
                "tuning" => ProjectKind::Tuning,
                k => return Err(format!("unknown kind {k:?}")),
            };
            let workload = args.opt_or("workload", "wordcount");
            create_template(&dir, kind, &workload, input_mb)?;
            println!("created {kind:?} project at {}", dir.display());
            println!("next: catla task --dir {}", dir.display());
            Ok(())
        }
        "sweep" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            print_spec_warnings(&project);
            let spec = project
                .spec
                .clone()
                .ok_or("sweep needs params.spec in the project")?;
            if spec.dims() == 0 {
                return Err(format!(
                    "params.spec declares no parameters for workload {:?}",
                    project.workload()?.name
                ));
            }
            let (k, n) = match args.opt("shard") {
                Some(s) => parse_shard(s)?,
                None => (0, 1),
            };
            let budget: usize = args.opt_parse_or("budget", usize::MAX)?;
            let workload = project.workload()?;
            let mut cluster = open_cluster(&project);
            println!("{}", cluster.describe());
            let space = catla::optim::ParamSpace::new(spec.clone(), project.base_config()?);
            let total = space.grid_cursor().total_points();
            let mut opt = catla::optim::GridSearch::new().sharded(k, n);
            let mut outcome = {
                let mut obj = catla::optim::ClusterObjective::new(&mut cluster, &workload, 1);
                catla::optim::Driver::new(budget).run(&mut opt, &space, &mut obj)?
            };
            outcome.optimizer = format!("grid[shard {k}/{n}]");
            let history = History::open(&dir).map_err(|e| e.to_string())?;
            let log_name = if n == 1 {
                "tuning_log.csv".to_string()
            } else {
                format!("tuning_log.shard{k}of{n}.csv")
            };
            let log_path = history.write_tuning_log_to(&log_name, &spec, &outcome)?;
            println!(
                "sweep shard {k}/{n}: {} of {total} grid points evaluated, best {:.1}s",
                outcome.evals(),
                outcome.best_value
            );
            println!("best configuration: {}", outcome.best_config.summary());
            println!("log: {}", log_path.display());
            Ok(())
        }
        "task" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            let mut cluster = open_cluster(&project);
            println!("{}", cluster.describe());
            let mut runner = TaskRunner::new(&mut cluster);
            let out = runner.run(&project)?;
            println!(
                "job {} finished: runtime {:.1}s (map phase {:.1}s), {} maps / {} reduces",
                out.job_id,
                out.metrics.runtime_s,
                out.metrics.map_phase_s,
                out.metrics.maps,
                out.metrics.reduces
            );
            println!("results downloaded to {}", out.results_dir.display());
            Ok(())
        }
        "project" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            let mut cluster = open_cluster(&project);
            println!("{}", cluster.describe());
            let out = ProjectRunner::new(&mut cluster).run(&project)?;
            println!("{} jobs completed:", out.jobs.len());
            for (name, m) in &out.jobs {
                println!(
                    "  {name:<24} {:>8.1}s  ({} maps, {} reduces)",
                    m.runtime_s, m.maps, m.reduces
                );
            }
            Ok(())
        }
        "tuning" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            print_spec_warnings(&project);
            let mut cluster = open_cluster(&project);
            println!("{}", cluster.describe());
            let prescreen = args.opt_or("prescreen", "off");
            let out = match prescreen.as_str() {
                "off" => OptimizerRunner::new(&mut cluster).run(&project)?,
                "native" => {
                    let mut scorer = NativeScorer {
                        workload: project.workload()?,
                        cluster: ClusterSpec::from_env(&project.env),
                    };
                    force_prescreen(&dir)?;
                    let project = Project::load(&dir)?;
                    OptimizerRunner::with_scorer(&mut cluster, &mut scorer).run(&project)?
                }
                "pjrt" => {
                    let rt = Runtime::open_default()?;
                    let mut scorer = CostModelExec::load(
                        &rt,
                        &project.workload()?,
                        &ClusterSpec::from_env(&project.env),
                    )?;
                    force_prescreen(&dir)?;
                    let project = Project::load(&dir)?;
                    OptimizerRunner::with_scorer(&mut cluster, &mut scorer).run(&project)?
                }
                other => return Err(format!("unknown --prescreen {other:?}")),
            };
            println!(
                "tuning finished: {} evaluations, best {:.1}s",
                out.outcome.evals(),
                out.outcome.best_value
            );
            println!("best configuration: {}", out.outcome.best_config.summary());
            println!("log: {}", out.log_path.display());
            // CatlaUI-style chart
            let history = History::open(&dir).map_err(|e| e.to_string())?;
            let csv = history.load_tuning_log()?;
            println!("{}", visualize::chart_from_tuning_log(&csv)?);
            Ok(())
        }
        "workflow" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            print_spec_warnings(&project);
            let mut jobs = catla::catla::workflow::from_project(&project)?;
            let mut cluster = open_cluster(&project);
            println!("{}", cluster.describe());
            if args.has_flag("tune") {
                let scoped = project
                    .scoped
                    .clone()
                    .ok_or("workflow --tune needs params.spec in the project")?;
                // same validated parsing + Driver (early stopping, trace
                // observer) as the `tuning` tool
                let (method, mut driver) = match &project.tuning {
                    Some(_) => {
                        let settings = TuningSettings::from_project(&project)?;
                        (
                            catla::optim::Method::from_name(&settings.optimizer, settings.seed)?,
                            settings.driver(),
                        )
                    }
                    None => (
                        catla::optim::Method::Bobyqa { seed: 7 },
                        catla::optim::Driver::new(40),
                    ),
                };
                let (tuned, merged) = catla::catla::workflow::tune_workflow(
                    &mut cluster,
                    &jobs,
                    &scoped,
                    project.base_config()?,
                    &method,
                    &mut driver,
                )?;
                println!(
                    "workflow tuning ({}): {} evaluations, best makespan {:.1}s",
                    tuned.optimizer,
                    tuned.evals(),
                    tuned.best_value
                );
                println!("merged configuration: {}", tuned.best_config.summary());
                // the merged log records scoped dims as <param>@<workload>
                // columns, so `replay`/resume reconstruction can rebuild
                // this exact space later
                let history = History::open(&dir).map_err(|e| e.to_string())?;
                let log_path = history.write_tuning_log(&merged.spec, &tuned)?;
                println!("log: {}", log_path.display());
                for j in &mut jobs {
                    j.job.config = merged.job_config(&tuned.best_config, &j.job.workload.name);
                }
                // per-job projections only differ on scoped specs
                if merged.spec.ranges.iter().any(|r| r.name().contains('@')) {
                    println!("per-job configurations:");
                    for j in &jobs {
                        println!("  {:<14} {}", j.job.name, j.job.config.summary());
                    }
                }
            }
            let out = catla::catla::workflow::run_workflow(&mut cluster, &jobs)?;
            println!("{:<14} {:>10} {:>10} {:>10}", "stage", "start_s", "finish_s", "runtime_s");
            for s in &out.stages {
                println!(
                    "{:<14} {:>10.1} {:>10.1} {:>10.1}",
                    s.name, s.start_s, s.finish_s, s.runtime_s
                );
            }
            println!("workflow makespan: {:.1}s", out.makespan_s);
            Ok(())
        }
        "ui" => {
            let dir = project_dir(args)?;
            print!("{}", catla::catla::dashboard::render(&dir)?);
            Ok(())
        }
        "tuning-group" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            print_spec_warnings(&project);
            let mut cluster = open_cluster(&project);
            println!("{}", cluster.describe());
            let out = catla::catla::multi_job::tune_group(&mut cluster, &project)?;
            println!(
                "group tuning finished ({}): {} evaluations, best aggregate {:.1}s",
                out.optimizer,
                out.evals(),
                out.best_value
            );
            println!("shared configuration: {}", out.best_config.summary());
            Ok(())
        }
        "resume" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            print_spec_warnings(&project);
            let default_budget = project
                .tuning
                .as_ref()
                .and_then(|t| t.get("budget"))
                .and_then(|s| s.parse().ok())
                .unwrap_or(60);
            let budget: usize = args.opt_parse_or("budget", default_budget)?;
            let mut cluster = open_cluster(&project);
            let out = catla::catla::resume::resume_tuning(&mut cluster, &project, budget)?;
            println!(
                "resumed ({}): {} total evaluations, best {:.1}s",
                out.optimizer,
                out.evals(),
                out.best_value
            );
            println!("best configuration: {}", out.best_config.summary());
            Ok(())
        }
        "replay" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            let n_jobs: usize = args.opt_parse_or("jobs", 100)?;
            let cl = ClusterSpec::from_env(&project.env);
            let gen = catla::hadoop::trace::TraceGen::default();
            let trace = gen.generate(n_jobs, cl.seed);
            // tuned config from the project's history (best logged row,
            // rebuilt against the exact space that produced the log —
            // flat or merged), else fall back to defaults-only replay
            let tuned = catla::catla::resume::best_logged_config(&project)
                .ok()
                .flatten();
            let before =
                catla::hadoop::trace::replay(&cl, &trace, &catla::config::params::HadoopConfig::default(), 7);
            println!(
                "default: makespan {:.1}h, mean wait {:.0}s, utilization {:.2}",
                before.makespan_s / 3600.0,
                before.mean_wait_s,
                before.utilization
            );
            match tuned {
                Some(cfg) => {
                    let after = catla::hadoop::trace::replay(&cl, &trace, &cfg, 7);
                    println!(
                        "tuned:   makespan {:.1}h, mean wait {:.0}s, utilization {:.2}  ({:.1}% makespan reduction)",
                        after.makespan_s / 3600.0,
                        after.mean_wait_s,
                        after.utilization,
                        (1.0 - after.makespan_s / before.makespan_s) * 100.0
                    );
                }
                None => println!("(no tuning history found — run `catla tuning` first for the comparison)"),
            }
            Ok(())
        }
        "aggregate" => {
            let dir = project_dir(args)?;
            let report = aggregate::aggregate(&dir)?;
            println!(
                "re-aggregated: {} histories found, {} rows in jobs.csv, {} tuning rows repaired",
                report.histories_found, report.jobs_csv_rows, report.tuning_rows_repaired
            );
            Ok(())
        }
        "visualize" => {
            let dir = project_dir(args)?;
            let history = History::open(&dir).map_err(|e| e.to_string())?;
            let csv = history.load_tuning_log()?;
            println!("{}", visualize::chart_from_tuning_log(&csv)?);
            if args.has_flag("gnuplot") {
                let script = visualize::gnuplot_fig3("history/tuning_log.csv", "fig3.png");
                let path = dir.join("history").join("fig3.gnuplot");
                catla::util::durable::atomic_write(&path, script.as_bytes())
                    .map_err(|e| e.to_string())?;
                println!("wrote {}", path.display());
            }
            Ok(())
        }
        "fsck" => {
            let dir = project_dir(args)?;
            let report = catla::catla::fsck::fsck_dir(&dir, args.has_flag("repair"))?;
            print!("{report}");
            if !report.problems.is_empty() {
                return Err(format!(
                    "{} unrepairable problem(s) — see above",
                    report.problems.len()
                ));
            }
            if !report.warnings.is_empty() && !args.has_flag("repair") {
                println!("re-run with --repair to fix the {} warning(s)", report.warnings.len());
            }
            Ok(())
        }
        "serve" => {
            // hidden fault hook: --crash-at <point> (or CATLA_CRASH_AT)
            // aborts the daemon the first time execution reaches the
            // named durability point — the crash-matrix tests drive it
            let crash_at = args
                .opt("crash-at")
                .map(str::to_string)
                .or_else(|| std::env::var("CATLA_CRASH_AT").ok().filter(|s| !s.is_empty()));
            if let Some(point) = crash_at {
                catla::util::crashpoint::arm(&point)?;
            }
            let threads: usize =
                args.opt_parse_or("threads", catla::util::pool::default_threads())?;
            let cache_entries: usize =
                args.opt_parse_or("cache-entries", catla::serve::DEFAULT_CACHE_ENTRIES)?;
            let queue: usize = args.opt_parse_or("queue", catla::serve::DEFAULT_QUEUE_CAP)?;
            let mut dispatcher =
                catla::serve::Dispatcher::new(threads, cache_entries).with_queue_cap(queue);
            // undocumented fault hook for the serve smoke's poison case:
            // --poison <id>:<n> makes the next n evaluation attempts
            // owned by session <id> panic, exercising the retry +
            // Failed-session path end to end
            if let Some(spec) = args.opt("poison") {
                let (id, n) = spec
                    .split_once(':')
                    .ok_or_else(|| format!("bad --poison {spec:?} (want <id>:<n>)"))?;
                let n: u64 = n
                    .parse()
                    .map_err(|_| format!("bad --poison count {n:?}"))?;
                dispatcher.inject_eval_faults(id, n);
            }
            let mut daemon = catla::serve::Daemon::new(dispatcher);
            eprintln!(
                "catla serve: {threads} workers, cache cap {cache_entries}, queue cap {queue}; \
                 line protocol on stdin/stdout (shutdown or EOF to stop)"
            );
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            daemon.serve(stdin.lock(), stdout.lock())
        }
        "describe" => {
            let dir = project_dir(args)?;
            let project = Project::load(&dir)?;
            let cluster = open_cluster(&project);
            println!("{}", cluster.describe());
            println!("workload: {:?}", project.workload()?);
            Ok(())
        }
        other => Err(format!("unknown tool {other:?}\n\n{USAGE}")),
    }
}

/// Ensure tuning.properties has prescreen=auto (CLI override).
fn force_prescreen(dir: &Path) -> Result<(), String> {
    let path = dir.join("tuning.properties");
    let mut text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
    if !text.contains("prescreen=") {
        text.push_str("prescreen=auto\n");
        catla::util::durable::atomic_write(&path, text.as_bytes()).map_err(|e| e.to_string())?;
    }
    Ok(())
}

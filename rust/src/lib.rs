//! # Catla — MapReduce performance self-tuning
//!
//! A from-scratch reproduction of *"An Open-Source Project for MapReduce
//! Performance Self-Tuning"* (Donghua Chen, 2019): the Catla self-tuning
//! system — Task Runner, Project Runner and Optimizer Runner over
//! direct-search and derivative-free optimization — built on a simulated
//! Hadoop 2.x substrate, with batched configuration scoring AOT-compiled
//! from JAX/Pallas and executed from rust via XLA PJRT.
//!
//! Layer map (DESIGN.md §3):
//! * [`catla`] — the paper's system: runners, projects, history, metrics.
//! * [`optim`] — grid/random/pattern searches and the BOBYQA-style DFO.
//! * [`hadoop`] — the simulated cluster substrate (DES engine).
//! * [`runtime`] — PJRT loader/executor for `artifacts/*.hlo.txt`.
//! * [`workloads`], [`config`], [`util`] — profiles, parameter metadata,
//!   and the hand-rolled foundations the offline image requires.

pub mod catla;
pub mod config;
pub mod hadoop;
pub mod optim;
pub mod runtime;
pub mod util;
pub mod workloads;

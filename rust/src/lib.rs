//! # Catla — MapReduce performance self-tuning
//!
//! A from-scratch reproduction of *"An Open-Source Project for MapReduce
//! Performance Self-Tuning"* (Donghua Chen, 2019): the Catla self-tuning
//! system — Task Runner, Project Runner and Optimizer Runner over
//! direct-search and derivative-free optimization — built on a simulated
//! Hadoop 2.x substrate, with batched configuration scoring AOT-compiled
//! from JAX/Pallas and executed via XLA PJRT (`pjrt` feature) or its
//! native f32 mirror (default).
//!
//! Layer map (DESIGN.md §3):
//! * [`catla`] — the paper's system: runners, projects, history, metrics.
//!   Every tuning entry point (Optimizer Runner, multi-job group tuning,
//!   workflow tuning, resume) drives search through the shared ask/tell
//!   core below.
//! * [`optim`] — the batched ask/tell optimizer core
//!   ([`optim::core::Optimizer`] / [`optim::core::Driver`] /
//!   [`optim::core::BatchObjective`]) and the eight methods behind it:
//!   grid/random/latin (population methods, whole-budget ask-batches) and
//!   coordinate/hooke-jeeves/nelder-mead/annealing/bobyqa (sequential,
//!   singleton asks), plus surrogate prescreening.
//! * [`hadoop`] — the simulated cluster substrate (DES engine). Batch
//!   evaluation reserves simulation seeds up front, so parallel scoring
//!   is byte-identical to serial submission.
//! * [`runtime`] — batched cost-model executor: PJRT loader for
//!   `artifacts/*.hlo.txt` with `--features pjrt`, native mirror
//!   otherwise.
//! * [`serve`] — tuning-as-a-service: a daemon multiplexing many
//!   concurrent sessions (each a [`serve::session::ServeSession`] in
//!   ask/tell form) over one persistent thread pool, with a global
//!   LRU memo-cache over simulation fingerprints.
//! * [`workloads`], [`config`], [`util`] — profiles, parameter metadata,
//!   and the hand-rolled foundations the offline image requires.

pub mod catla;
pub mod config;
pub mod hadoop;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod util;
pub mod workloads;

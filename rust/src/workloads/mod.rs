//! MapReduce workload models.
//!
//! Against a real cluster, Catla ships a user jar; here a workload is a
//! resource profile — the quantities through which a job's jar actually
//! influences running time (input volume, map selectivity, CPU cost per
//! byte, record sizes, key skew). The five canonical Hadoop example jobs
//! the paper's audience tunes are provided.

pub mod suite;

pub use suite::{grep, join, pagerank_iteration, terasort, wordcount};

/// Resource profile of one MapReduce job binary + dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    /// Total input size in MB.
    pub input_mb: f64,
    /// map output bytes / map input bytes (after combiner, if any).
    pub map_selectivity: f64,
    /// Seconds of map-function CPU per MB of input.
    pub cpu_per_mb_map: f64,
    /// Seconds of reduce-function CPU per MB of reduce input.
    pub cpu_per_mb_red: f64,
    /// Compressed size / raw size for map output (codec-dependent).
    pub compress_ratio: f64,
    /// reduce output bytes / reduce input bytes.
    pub output_selectivity: f64,
    /// Average record size in KB (drives sort-CPU estimates).
    pub record_kb: f64,
    /// Zipf-ish skew of reduce keys: 0 = uniform partitions,
    /// 1 = heavily skewed (one hot reducer gets ~2x the mean).
    pub key_skew: f64,
}

impl WorkloadSpec {
    /// Scale the dataset, keeping per-byte characteristics.
    pub fn with_input_mb(mut self, input_mb: f64) -> Self {
        self.input_mb = input_mb;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.input_mb <= 0.0 {
            return Err("input_mb must be positive".into());
        }
        for (name, v, lo, hi) in [
            ("map_selectivity", self.map_selectivity, 0.0, 100.0),
            ("cpu_per_mb_map", self.cpu_per_mb_map, 0.0, 10.0),
            ("cpu_per_mb_red", self.cpu_per_mb_red, 0.0, 10.0),
            ("compress_ratio", self.compress_ratio, 0.01, 1.0),
            ("output_selectivity", self.output_selectivity, 0.0, 100.0),
            ("record_kb", self.record_kb, 1e-4, 1e4),
            ("key_skew", self.key_skew, 0.0, 1.0),
        ] {
            if !(lo..=hi).contains(&v) {
                return Err(format!("{name} = {v} outside [{lo}, {hi}]"));
            }
        }
        Ok(())
    }
}

/// Look up a built-in workload by name (used by project templates).
pub fn by_name(name: &str, input_mb: f64) -> Option<WorkloadSpec> {
    let w = match name {
        "wordcount" => wordcount(input_mb),
        "terasort" => terasort(input_mb),
        "grep" => grep(input_mb),
        "join" => join(input_mb),
        "pagerank" => pagerank_iteration(input_mb),
        _ => return None,
    };
    Some(w)
}

pub const BUILTIN_NAMES: [&str; 5] = ["wordcount", "terasort", "grep", "join", "pagerank"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_validate() {
        for name in BUILTIN_NAMES {
            let w = by_name(name, 1024.0).unwrap();
            w.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(w.input_mb, 1024.0);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("sleepjob", 1.0).is_none());
    }

    #[test]
    fn terasort_moves_everything() {
        // terasort is the IO-bound extreme: selectivity 1.0, no combiner
        let t = terasort(1024.0);
        assert!((t.map_selectivity - 1.0).abs() < 1e-9);
        assert!(t.output_selectivity >= 0.99);
    }

    #[test]
    fn grep_is_map_side_selective() {
        let g = grep(1024.0);
        assert!(g.map_selectivity < 0.05);
    }
}

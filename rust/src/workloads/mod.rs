//! MapReduce workload models.
//!
//! Against a real cluster, Catla ships a user jar; here a workload is a
//! resource profile — the quantities through which a job's jar actually
//! influences running time (input volume, map selectivity, CPU cost per
//! byte, record sizes, key skew). The five canonical Hadoop example jobs
//! the paper's audience tunes are provided.

pub mod suite;

pub use suite::{grep, join, pagerank_iteration, terasort, wordcount};

/// Resource profile of one MapReduce job binary + dataset.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    /// Suggested per-workload tuning block: the `param`/`constraint`
    /// lines worth scoping to this suite in a `workload <name> { ... }`
    /// block of `params.spec` (shuffle-heavy suites tune codec +
    /// parallelcopies, CPU-bound suites memory + slowstart, …).
    /// Rendered by [`suggested_scoped_spec`] / `catla template
    /// --workloads`; never applied implicitly — explicit blocks in the
    /// project's spec are the only thing tuning runs read.
    pub tuning_spec: Option<&'static str>,
    /// Total input size in MB.
    pub input_mb: f64,
    /// map output bytes / map input bytes (after combiner, if any).
    pub map_selectivity: f64,
    /// Seconds of map-function CPU per MB of input.
    pub cpu_per_mb_map: f64,
    /// Seconds of reduce-function CPU per MB of reduce input.
    pub cpu_per_mb_red: f64,
    /// Compressed size / raw size for map output (codec-dependent).
    pub compress_ratio: f64,
    /// reduce output bytes / reduce input bytes.
    pub output_selectivity: f64,
    /// Average record size in KB (drives sort-CPU estimates).
    pub record_kb: f64,
    /// Zipf-ish skew of reduce keys: 0 = uniform partitions,
    /// 1 = heavily skewed (one hot reducer gets ~2x the mean).
    pub key_skew: f64,
}

impl WorkloadSpec {
    /// Scale the dataset, keeping per-byte characteristics.
    pub fn with_input_mb(mut self, input_mb: f64) -> Self {
        self.input_mb = input_mb;
        self
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.input_mb <= 0.0 {
            return Err("input_mb must be positive".into());
        }
        for (name, v, lo, hi) in [
            ("map_selectivity", self.map_selectivity, 0.0, 100.0),
            ("cpu_per_mb_map", self.cpu_per_mb_map, 0.0, 10.0),
            ("cpu_per_mb_red", self.cpu_per_mb_red, 0.0, 10.0),
            ("compress_ratio", self.compress_ratio, 0.01, 1.0),
            ("output_selectivity", self.output_selectivity, 0.0, 100.0),
            ("record_kb", self.record_kb, 1e-4, 1e4),
            ("key_skew", self.key_skew, 0.0, 1.0),
        ] {
            if !(lo..=hi).contains(&v) {
                return Err(format!("{name} = {v} outside [{lo}, {hi}]"));
            }
        }
        Ok(())
    }
}

/// Look up a built-in workload by name (used by project templates).
pub fn by_name(name: &str, input_mb: f64) -> Option<WorkloadSpec> {
    let w = match name {
        "wordcount" => wordcount(input_mb),
        "terasort" => terasort(input_mb),
        "grep" => grep(input_mb),
        "join" => join(input_mb),
        "pagerank" => pagerank_iteration(input_mb),
        _ => return None,
    };
    Some(w)
}

pub const BUILTIN_NAMES: [&str; 5] = ["wordcount", "terasort", "grep", "join", "pagerank"];

/// Render a scoped `params.spec` for a suite of workloads: a small
/// shared block plus each workload's suggested `workload { ... }` block
/// (suites without an attachment contribute no block and tune the
/// shared dims only). The output parses with
/// [`crate::config::scope::ScopedSpec::parse`].
pub fn suggested_scoped_spec(workloads: &[&WorkloadSpec]) -> String {
    let mut out = String::from(
        "# Catla scoped tuning specification\n\
         # shared block: tuned once, applied to every job\n\
         param mapreduce.job.reduces int 1 64\n",
    );
    for w in workloads {
        let Some(block) = w.tuning_spec else { continue };
        out.push_str(&format!("\nworkload {} {{\n", w.name));
        for line in block.lines() {
            let line = line.trim();
            if !line.is_empty() {
                out.push_str("  ");
                out.push_str(line);
                out.push('\n');
            }
        }
        out.push_str("}\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_validate() {
        for name in BUILTIN_NAMES {
            let w = by_name(name, 1024.0).unwrap();
            w.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(w.input_mb, 1024.0);
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("sleepjob", 1.0).is_none());
    }

    #[test]
    fn suite_tuning_attachments_parse_standalone_and_merged() {
        use crate::config::scope::ScopedSpec;
        use crate::config::spec::TuningSpec;
        // every attached block is a valid flat spec fragment...
        for name in BUILTIN_NAMES {
            let w = by_name(name, 1024.0).unwrap();
            let block = w.tuning_spec.unwrap_or_else(|| panic!("{name}: no attachment"));
            let spec = TuningSpec::parse(block).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(spec.dims() >= 1, "{name}: empty attachment");
            assert!(spec.warnings.is_empty(), "{name}: {:?}", spec.warnings);
        }
        // ...and the rendered suite file parses as a scoped spec whose
        // blocks own exactly their attached params
        let all: Vec<WorkloadSpec> = BUILTIN_NAMES
            .iter()
            .map(|n| by_name(n, 1024.0).unwrap())
            .collect();
        let refs: Vec<&WorkloadSpec> = all.iter().collect();
        let text = suggested_scoped_spec(&refs);
        let scoped = ScopedSpec::parse(&text).unwrap();
        assert_eq!(scoped.scopes.len(), 5);
        assert!(scoped.warnings.is_empty(), "{:?}", scoped.warnings);
        let names: Vec<&str> = BUILTIN_NAMES.to_vec();
        let merged = scoped.merge(&names).unwrap();
        // shared reduces + every block's scoped dims
        assert!(merged.dims() > scoped.global.dims());
    }

    #[test]
    fn terasort_moves_everything() {
        // terasort is the IO-bound extreme: selectivity 1.0, no combiner
        let t = terasort(1024.0);
        assert!((t.map_selectivity - 1.0).abs() < 1e-9);
        assert!(t.output_selectivity >= 0.99);
    }

    #[test]
    fn grep_is_map_side_selective() {
        let g = grep(1024.0);
        assert!(g.map_selectivity < 0.05);
    }
}

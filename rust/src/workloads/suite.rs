//! The built-in workload profiles.
//!
//! Numbers are order-of-magnitude profiles of the stock Hadoop examples
//! on 2010s-era cluster hardware; EXPERIMENTS.md only relies on their
//! *relative* characteristics (CPU-bound vs shuffle-bound vs IO-bound).

use super::WorkloadSpec;

/// WordCount with combiner — the paper's experiment workload.
/// CPU-ish maps, combiner shrinks shuffle to ~30%.
pub fn wordcount(input_mb: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "wordcount".into(),
        tuning_spec: Some(
            "# CPU-bound with a combiner: task memory + reduce overlap matter most\n\
             param mapreduce.map.memory.mb int 512 4096 log\n\
             param mapreduce.job.reduce.slowstart.completedmaps float 0.05 1.0",
        ),
        input_mb,
        map_selectivity: 0.30,
        cpu_per_mb_map: 0.012,
        cpu_per_mb_red: 0.006,
        compress_ratio: 0.35,
        output_selectivity: 0.10,
        record_kb: 0.05,
        key_skew: 0.35, // natural-language word frequencies are skewed
    }
}

/// TeraSort — pure shuffle/IO stress: every byte is mapped, shuffled,
/// sorted and written back (replicated).
pub fn terasort(input_mb: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "terasort".into(),
        tuning_spec: Some(
            "# pure shuffle/IO stress: wire bytes + copy parallelism matter most\n\
             param mapreduce.map.output.compress bool\n\
             param mapreduce.reduce.shuffle.parallelcopies int 1 64",
        ),
        input_mb,
        map_selectivity: 1.0,
        cpu_per_mb_map: 0.002,
        cpu_per_mb_red: 0.002,
        compress_ratio: 0.85, // random keys compress poorly
        output_selectivity: 1.0,
        record_kb: 0.1,
        key_skew: 0.0, // sampled partitioner balances ranges
    }
}

/// Grep (distributed) — highly selective maps, negligible shuffle.
pub fn grep(input_mb: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "grep".into(),
        tuning_spec: Some(
            "# map-side selective scan: split geometry dominates\n\
             param mapreduce.input.fileinputformat.split.mb int 32 512",
        ),
        input_mb,
        map_selectivity: 0.01,
        cpu_per_mb_map: 0.008,
        cpu_per_mb_red: 0.004,
        compress_ratio: 0.40,
        output_selectivity: 1.0,
        record_kb: 0.2,
        key_skew: 0.1,
    }
}

/// Repartition join of two tables — shuffle-heavy with skewed keys
/// (the MRTune-style stress case).
pub fn join(input_mb: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "join".into(),
        tuning_spec: Some(
            "# skewed shuffle: reducer memory + copy parallelism matter most\n\
             param mapreduce.reduce.memory.mb int 512 8192 log\n\
             param mapreduce.reduce.shuffle.parallelcopies int 1 64",
        ),
        input_mb,
        map_selectivity: 1.05, // tagging adds a little
        cpu_per_mb_map: 0.005,
        cpu_per_mb_red: 0.010,
        compress_ratio: 0.55,
        output_selectivity: 0.60,
        record_kb: 0.5,
        key_skew: 0.7,
    }
}

/// One PageRank power iteration — moderate shuffle, CPU-lean,
/// rank mass concentrated on high-degree vertices.
pub fn pagerank_iteration(input_mb: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "pagerank".into(),
        tuning_spec: Some(
            "# many tiny records: sort buffer geometry dominates map cost\n\
             param mapreduce.task.io.sort.mb int 16 2048 log\n\
             param mapreduce.map.sort.spill.percent float 0.5 0.95",
        ),
        input_mb,
        map_selectivity: 0.80,
        cpu_per_mb_map: 0.006,
        cpu_per_mb_red: 0.008,
        compress_ratio: 0.45,
        output_selectivity: 0.50,
        record_kb: 0.03,
        key_skew: 0.6,
    }
}

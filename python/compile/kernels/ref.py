"""Pure-jnp oracle for the Catla analytic cost model and the quadratic
surrogate.  The pallas kernels in `costmodel.py` / `quadratic.py` must match
these to float tolerance; pytest+hypothesis enforces it.

The arithmetic lives in `phase_math` so the pallas kernel bodies can reuse
the *same* expression graph on VMEM blocks while this module applies it to
whole arrays — the tests then validate the pallas plumbing (BlockSpec
tiling, grid, padding) rather than two hand-copies of the formulas.
"""

import jax.numpy as jnp

from .. import spec as S

_EPS = 1e-6


def phase_math(cfg, consts):
    """Compute phase-time channels for a batch of configurations.

    cfg:    f32[N, N_PARAMS] -- Hadoop parameter vectors
    consts: f32[N_CONSTS]    -- workload + cluster descriptor
    returns f32[N, N_PHASES] -- per-phase seconds (uncalibrated)
    """
    f32 = jnp.float32

    def c(i):
        return consts[i].astype(f32)

    reduces = jnp.maximum(cfg[:, S.P_REDUCES], 1.0)
    sort_mb = jnp.maximum(cfg[:, S.P_IO_SORT_MB], 1.0)
    sort_factor = jnp.maximum(cfg[:, S.P_SORT_FACTOR], 2.0)
    spill_pct = jnp.clip(cfg[:, S.P_SPILL_PERCENT], 0.05, 1.0)
    pcopies = jnp.maximum(cfg[:, S.P_PARALLEL_COPIES], 1.0)
    slowstart = jnp.clip(cfg[:, S.P_SLOWSTART], 0.0, 1.0)
    map_mem = jnp.maximum(cfg[:, S.P_MAP_MEM_MB], 128.0)
    red_mem = jnp.maximum(cfg[:, S.P_RED_MEM_MB], 128.0)
    compress = jnp.clip(cfg[:, S.P_COMPRESS], 0.0, 1.0)
    split_mb = jnp.maximum(cfg[:, S.P_SPLIT_MB], 1.0)

    input_mb = jnp.maximum(c(S.C_INPUT_MB), 1.0)
    sel = c(S.C_MAP_SELECTIVITY)
    cpu_map = c(S.C_CPU_PER_MB_MAP)
    cpu_red = c(S.C_CPU_PER_MB_RED)
    nodes = jnp.maximum(c(S.C_NODES), 1.0)
    node_mem = jnp.maximum(c(S.C_MEM_PER_NODE_MB), 256.0)
    vcores = jnp.maximum(c(S.C_VCORES), 1.0)
    disk = jnp.maximum(c(S.C_DISK_MBS), _EPS)
    net = jnp.maximum(c(S.C_NET_MBS), _EPS)
    cratio = c(S.C_COMPRESS_RATIO)
    out_sel = c(S.C_OUTPUT_SELECTIVITY)
    repl = jnp.maximum(c(S.C_REPLICATION), 1.0)
    t_task = c(S.C_TASK_OVERHEAD_S)
    t_am = c(S.C_AM_OVERHEAD_S)
    record_kb = jnp.maximum(c(S.C_RECORD_KB), 1e-4)
    locality = jnp.clip(c(S.C_LOCALITY), 0.0, 1.0)

    # ---- task counts and container waves --------------------------------
    maps = jnp.ceil(input_mb / split_mb)
    map_slots = nodes * jnp.maximum(
        1.0, jnp.minimum(jnp.floor(node_mem / map_mem), vcores)
    )
    red_slots = nodes * jnp.maximum(
        1.0, jnp.minimum(jnp.floor(node_mem / red_mem), vcores)
    )
    map_waves = jnp.ceil(maps / map_slots)
    red_waves = jnp.ceil(reduces / red_slots)

    # ---- map task --------------------------------------------------------
    mb_per_map = input_mb / maps
    read_rate = disk * (locality + (1.0 - locality) * 0.6)
    t_read = mb_per_map / read_rate

    t_map_fn = mb_per_map * cpu_map
    map_out = mb_per_map * sel  # logical (uncompressed) map output, MB
    disk_out = map_out * (1.0 - compress * (1.0 - cratio))

    buf = sort_mb * spill_pct
    spills = jnp.maximum(1.0, jnp.ceil(map_out / jnp.maximum(buf, _EPS)))
    # in-memory sort CPU: n log n over the records of each buffer fill
    buf_records = jnp.maximum(2.0, jnp.minimum(map_out, buf) * 1024.0 / record_kb)
    t_sort = map_out * cpu_map * 0.25 * jnp.log2(buf_records) / 20.0
    t_compress = map_out * cpu_map * 0.30 * compress

    t_spill_io = disk_out / disk
    merge_passes = jnp.where(
        spills > 1.0,
        jnp.ceil(jnp.log(spills) / jnp.log(sort_factor)),
        0.0,
    )
    t_merge_io = merge_passes * 2.0 * disk_out / disk

    # ---- shuffle ---------------------------------------------------------
    total_shuffle = maps * disk_out  # MB moved over the network
    per_red = total_shuffle / reduces
    copy_eff = net * (0.4 + 0.6 * jnp.minimum(pcopies, 16.0) / 16.0)
    active_red = jnp.minimum(reduces, red_slots)
    fair_share = net * nodes / jnp.maximum(active_red, 1.0)
    rate = jnp.minimum(copy_eff, fair_share)
    t_copy = per_red / jnp.maximum(rate, _EPS)

    map_phase = map_waves * (t_read + t_map_fn + t_sort + t_compress
                             + t_spill_io + t_merge_io)
    # shuffle overlaps the map phase once `slowstart` of maps completed
    overlap = (1.0 - slowstart) * map_phase
    shuffle_tail = jnp.maximum(t_copy - overlap, t_copy * 0.05)
    # reducers started early squat on containers while maps still need them
    squat = (1.0 - slowstart) * 0.05 * map_phase * jnp.minimum(
        reduces / jnp.maximum(red_slots, 1.0), 1.0
    )
    shuffle_ch = shuffle_tail + squat

    # ---- reduce task -----------------------------------------------------
    per_red_logical = maps * map_out / reduces
    t_decompress = per_red_logical * cpu_map * 0.10 * compress
    merge_passes_r = jnp.maximum(
        jnp.ceil(jnp.log(jnp.maximum(maps, 2.0)) / jnp.log(sort_factor)) - 1.0,
        0.0,
    )
    in_memory = per_red <= 0.70 * red_mem
    t_rmerge = jnp.where(
        in_memory, 0.0, merge_passes_r * 2.0 * per_red / disk
    )
    t_red_fn = per_red_logical * cpu_red
    out_mb = per_red_logical * out_sel
    t_write = out_mb * repl / disk

    # ---- assemble channels (already wave-multiplied) ---------------------
    ph = jnp.stack(
        [
            map_waves * t_read,
            map_waves * (t_map_fn + t_sort + t_compress),
            map_waves * (t_spill_io + t_merge_io),
            shuffle_ch,
            red_waves * t_rmerge,
            red_waves * (t_red_fn + t_decompress),
            red_waves * t_write,
            t_am + (map_waves + red_waves) * t_task,
        ],
        axis=-1,
    )
    return ph


def cost_model_ref(cfg, consts, weights):
    """Reference batched cost model.

    Returns (runtime f32[N], phases f32[N, N_PHASES]) where
    runtime = sum(phases @ weights, axis=-1).
    """
    ph = phase_math(cfg, consts)
    calibrated = ph @ weights
    return jnp.sum(calibrated, axis=-1), ph


def quadratic_ref(x, g, h, c0):
    """Reference batched quadratic surrogate.

    q(x) = c0 + x.g + 0.5 * x^T H x  for each row of x.
    x: f32[N, D], g: f32[D], h: f32[D, D], c0: f32[] -> f32[N]
    """
    lin = x @ g
    quad = 0.5 * jnp.sum((x @ h) * x, axis=-1)
    return c0 + lin + quad

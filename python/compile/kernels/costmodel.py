"""L1 pallas kernel: batched Hadoop-config -> phase-times/runtime scoring.

The hot-spot of Catla's surrogate-assisted tuning is scoring large batches
of candidate configurations against the analytic cost model.  The kernel
tiles the batch axis N into BLOCK_N-row blocks (VMEM-resident), computes
the phase channels elementwise (VPU work) and applies the [N_PHASES x
N_PHASES] calibration matmul (MXU work on real TPU).  `consts` and
`weights` stay resident across the whole grid.

interpret=True always: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU performance is estimated analytically in
DESIGN.md / EXPERIMENTS.md (Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import spec as S
from . import ref


def _kernel(cfg_ref, consts_ref, w_ref, runtime_ref, phases_ref):
    cfg = cfg_ref[...]
    consts = consts_ref[...]
    w = w_ref[...]
    ph = ref.phase_math(cfg, consts)
    calibrated = jnp.dot(ph, w, preferred_element_type=jnp.float32)
    phases_ref[...] = ph
    runtime_ref[...] = jnp.sum(calibrated, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_n",))
def cost_model_pallas(cfg, consts, weights, *, block_n: int = S.BLOCK_N):
    """Batched cost model as a pallas_call.

    cfg: f32[N, N_PARAMS] with N a multiple of `block_n`
    consts: f32[N_CONSTS]; weights: f32[N_PHASES, N_PHASES]
    returns (runtime f32[N], phases f32[N, N_PHASES])
    """
    n = cfg.shape[0]
    if n % block_n != 0:
        raise ValueError(f"batch {n} not a multiple of block {block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, S.N_PARAMS), lambda i: (i, 0)),
            # consts + weights: one block covering the whole array, reused
            # by every grid step (index_map pins block 0).
            pl.BlockSpec((S.N_CONSTS,), lambda i: (0,)),
            pl.BlockSpec((S.N_PHASES, S.N_PHASES), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, S.N_PHASES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n, S.N_PHASES), jnp.float32),
        ],
        interpret=True,
    )(cfg, consts, weights)

"""L1 pallas kernel: batched quadratic-surrogate evaluation.

BOBYQA-style DFO builds a quadratic model q(x) = c + g.x + 0.5 x^T H x of
the (noisy) job running time; surrogate prescreening evaluates q over many
candidate points per iteration.  The kernel blocks the candidate batch and
evaluates the quadratic form with two small matmuls per block.

interpret=True: see costmodel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import spec as S


def _kernel(x_ref, g_ref, h_ref, c_ref, q_ref):
    x = x_ref[...]
    g = g_ref[...]
    h = h_ref[...]
    c0 = c_ref[0]
    lin = jnp.dot(x, g[:, None], preferred_element_type=jnp.float32)[:, 0]
    xh = jnp.dot(x, h, preferred_element_type=jnp.float32)
    quad = 0.5 * jnp.sum(xh * x, axis=-1)
    q_ref[...] = c0 + lin + quad


@functools.partial(jax.jit, static_argnames=("block_n",))
def quadratic_pallas(x, g, h, c0, *, block_n: int = S.BLOCK_N):
    """Batched quadratic form.

    x: f32[N, D] (N multiple of block_n), g: f32[D], h: f32[D, D],
    c0: f32[1] -> q: f32[N]
    """
    n, d = x.shape
    if n % block_n != 0:
        raise ValueError(f"batch {n} not a multiple of block {block_n}")
    grid = (n // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, g, h, c0)

"""Shared specification of the Catla analytic cost model.

Single source of truth (python side) for:
  * the order of Hadoop configuration parameters in a config vector,
  * the order of workload/cluster constants in the consts vector,
  * the phase channels produced by the model,
  * the default calibration matrix.

The rust simulator (`rust/src/hadoop/costmodel.rs`) mirrors these indices
and formulas; integration tests compare the two through the AOT artifacts.
The rust parameter table lives in `rust/src/config/space.rs`
(`builtin_defs()`): its first N_PARAMS rows are the stable AOT-artifact
prefix in exactly this order — spec-declared extra parameters are
appended after the prefix and never enter the artifact row.

Units: **megabytes** and **seconds** everywhere (f32 stays well inside its
7 significant digits for multi-TB inputs expressed in MB).
"""

import numpy as np

# ---------------------------------------------------------------- params --
# Hadoop configuration parameters, in config-vector order.
P_REDUCES = 0  # mapreduce.job.reduces
P_IO_SORT_MB = 1  # mapreduce.task.io.sort.mb
P_SORT_FACTOR = 2  # mapreduce.task.io.sort.factor
P_SPILL_PERCENT = 3  # mapreduce.map.sort.spill.percent
P_PARALLEL_COPIES = 4  # mapreduce.reduce.shuffle.parallelcopies
P_SLOWSTART = 5  # mapreduce.job.reduce.slowstart.completedmaps
P_MAP_MEM_MB = 6  # mapreduce.map.memory.mb
P_RED_MEM_MB = 7  # mapreduce.reduce.memory.mb
P_COMPRESS = 8  # mapreduce.map.output.compress (0/1)
P_SPLIT_MB = 9  # effective input split size (dfs.blocksize / minsize)
N_PARAMS = 10

PARAM_NAMES = [
    "mapreduce.job.reduces",
    "mapreduce.task.io.sort.mb",
    "mapreduce.task.io.sort.factor",
    "mapreduce.map.sort.spill.percent",
    "mapreduce.reduce.shuffle.parallelcopies",
    "mapreduce.job.reduce.slowstart.completedmaps",
    "mapreduce.map.memory.mb",
    "mapreduce.reduce.memory.mb",
    "mapreduce.map.output.compress",
    "mapreduce.input.fileinputformat.split.mb",
]

# Box bounds used by optimizers and by the hypothesis test generators.
PARAM_LO = np.array([1, 16, 2, 0.50, 1, 0.05, 512, 512, 0, 32], np.float32)
PARAM_HI = np.array(
    [64, 2048, 128, 0.95, 64, 1.00, 4096, 8192, 1, 512], np.float32
)

# ---------------------------------------------------------------- consts --
# Workload + cluster descriptor, in consts-vector order.
C_INPUT_MB = 0  # total job input size
C_MAP_SELECTIVITY = 1  # map output bytes / input bytes
C_CPU_PER_MB_MAP = 2  # seconds of map-function CPU per MB
C_CPU_PER_MB_RED = 3  # seconds of reduce-function CPU per MB
C_NODES = 4  # worker node count
C_MEM_PER_NODE_MB = 5  # NodeManager memory
C_VCORES = 6  # vcores per node
C_DISK_MBS = 7  # sequential disk MB/s
C_NET_MBS = 8  # per-node network MB/s
C_COMPRESS_RATIO = 9  # compressed size / raw size
C_OUTPUT_SELECTIVITY = 10  # reduce output bytes / reduce input bytes
C_REPLICATION = 11  # HDFS replication of job output
C_TASK_OVERHEAD_S = 12  # container launch + JVM start per task
C_AM_OVERHEAD_S = 13  # job setup/teardown (AM) seconds
C_RECORD_KB = 14  # average record size in KB
C_LOCALITY = 15  # fraction of node-local map input reads
N_CONSTS = 16

# ---------------------------------------------------------------- phases --
PH_READ = 0  # map input read
PH_MAP_CPU = 1  # map function + sort + compress CPU
PH_MAP_IO = 2  # spill + map-side merge disk IO
PH_SHUFFLE = 3  # non-overlapped shuffle copy tail
PH_RED_IO = 4  # reduce-side merge disk IO
PH_RED_CPU = 5  # reduce function CPU
PH_WRITE = 6  # HDFS output write
PH_OVERHEAD = 7  # AM + per-wave scheduling overhead
N_PHASES = 8

PHASE_NAMES = [
    "read",
    "map_cpu",
    "map_io",
    "shuffle",
    "red_io",
    "red_cpu",
    "write",
    "overhead",
]


def default_weights() -> np.ndarray:
    """Default phase-calibration matrix W [N_PHASES, N_PHASES].

    runtime = sum(phases @ W, axis=-1).  Identity plus small off-diagonal
    overlap discounts: map CPU hides a slice of map IO, reduce CPU hides a
    slice of reduce IO.
    """
    w = np.eye(N_PHASES, dtype=np.float32)
    w[PH_MAP_CPU, PH_MAP_IO] = -0.08
    w[PH_RED_CPU, PH_RED_IO] = -0.05
    return w


def wordcount_consts(input_mb: float = 10240.0, nodes: int = 16) -> np.ndarray:
    """Consts vector for the paper's WordCount experiment."""
    c = np.zeros(N_CONSTS, np.float32)
    c[C_INPUT_MB] = input_mb
    c[C_MAP_SELECTIVITY] = 0.30  # wordcount emits (word, 1) pairs, combiner on
    c[C_CPU_PER_MB_MAP] = 0.012
    c[C_CPU_PER_MB_RED] = 0.006
    c[C_NODES] = nodes
    c[C_MEM_PER_NODE_MB] = 8192
    c[C_VCORES] = 8
    c[C_DISK_MBS] = 120.0
    c[C_NET_MBS] = 110.0
    c[C_COMPRESS_RATIO] = 0.35
    c[C_OUTPUT_SELECTIVITY] = 0.10
    c[C_REPLICATION] = 3
    c[C_TASK_OVERHEAD_S] = 1.2
    c[C_AM_OVERHEAD_S] = 8.0
    c[C_RECORD_KB] = 0.05
    c[C_LOCALITY] = 0.85
    return c


# AOT batch sizes emitted by aot.py; the rust runtime pads batches up to
# the nearest available size.
AOT_BATCH_SIZES = (128, 1024)
QUAD_DIM = 8  # quadratic surrogate dimension (optimizers pad with zeros)
QUAD_BATCH = 256
BLOCK_N = 128  # pallas block size along the config-batch axis

"""L2: the jax compute graphs Catla AOT-compiles for its rust runtime.

Two graphs, both calling the L1 pallas kernels:

  * `cost_model`      — batched analytic Hadoop cost model (configs ->
                        predicted runtimes + phase breakdown)
  * `quadratic_eval`  — batched quadratic-surrogate evaluation for
                        DFO prescreening

Build-time only: `aot.py` lowers these once to HLO text; the rust
coordinator loads and executes the artifacts via PJRT.  Python is never on
the tuning request path.
"""

import jax.numpy as jnp

from . import spec as S
from .kernels.costmodel import cost_model_pallas
from .kernels.quadratic import quadratic_pallas


def cost_model(cfg, consts, weights):
    """configs f32[N, N_PARAMS], consts f32[N_CONSTS],
    weights f32[N_PHASES, N_PHASES] -> (runtime f32[N], phases f32[N, K])."""
    cfg = cfg.astype(jnp.float32)
    runtime, phases = cost_model_pallas(cfg, consts, weights)
    return runtime, phases


def quadratic_eval(x, g, h, c0):
    """x f32[N, D], g f32[D], h f32[D, D], c0 f32[1] -> q f32[N]."""
    return quadratic_pallas(x.astype(jnp.float32), g, h, c0)


def pad_batch(arr, batch):
    """Pad the leading axis of `arr` with its last row up to `batch` rows.

    Mirrors what the rust runtime does before invoking the fixed-shape
    AOT executable; exposed for tests.
    """
    n = arr.shape[0]
    if n == batch:
        return arr
    if n > batch:
        raise ValueError(f"batch {n} exceeds artifact batch {batch}")
    pad = jnp.repeat(arr[-1:], batch - n, axis=0)
    return jnp.concatenate([arr, pad], axis=0)

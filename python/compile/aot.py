"""AOT-lower the L2 graphs to HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published `xla` 0.1.6 crate binds) rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Emits:
  costmodel_n{128,1024}.hlo.txt   (configs, consts, weights) -> tuple(runtime, phases)
  quadratic_n256.hlo.txt          (x, g, h, c0) -> tuple(q)
  manifest.txt                    shapes the rust runtime asserts against
"""

import argparse
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from . import spec as S


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_cost_model(n: int) -> str:
    cfg = jax.ShapeDtypeStruct((n, S.N_PARAMS), np.float32)
    consts = jax.ShapeDtypeStruct((S.N_CONSTS,), np.float32)
    weights = jax.ShapeDtypeStruct((S.N_PHASES, S.N_PHASES), np.float32)
    return to_hlo_text(jax.jit(model.cost_model).lower(cfg, consts, weights))


def lower_quadratic(n: int, d: int) -> str:
    x = jax.ShapeDtypeStruct((n, d), np.float32)
    g = jax.ShapeDtypeStruct((d,), np.float32)
    h = jax.ShapeDtypeStruct((d, d), np.float32)
    c0 = jax.ShapeDtypeStruct((1,), np.float32)
    return to_hlo_text(jax.jit(model.quadratic_eval).lower(x, g, h, c0))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for n in S.AOT_BATCH_SIZES:
        name = f"costmodel_n{n}.hlo.txt"
        text = lower_cost_model(n)
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        manifest.append(
            f"{name} cost_model n={n} params={S.N_PARAMS} "
            f"consts={S.N_CONSTS} phases={S.N_PHASES}"
        )
        print(f"wrote {name}: {len(text)} chars")

    name = f"quadratic_n{S.QUAD_BATCH}.hlo.txt"
    text = lower_quadratic(S.QUAD_BATCH, S.QUAD_DIM)
    with open(os.path.join(args.out_dir, name), "w") as f:
        f.write(text)
    manifest.append(f"{name} quadratic n={S.QUAD_BATCH} dim={S.QUAD_DIM}")
    print(f"wrote {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()

"""Kernel-vs-oracle correctness: the CORE numeric signal for L1.

hypothesis sweeps batch shapes, block sizes, parameter ranges and dtypes;
every pallas result must match the pure-jnp reference to float tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import spec as S
from compile.kernels import ref
from compile.kernels.costmodel import cost_model_pallas
from compile.kernels.quadratic import quadratic_pallas

RNG = np.random.default_rng(0)


def random_configs(n, rng=None, dtype=np.float32):
    rng = rng or RNG
    u = rng.random((n, S.N_PARAMS), np.float32)
    cfg = S.PARAM_LO + u * (S.PARAM_HI - S.PARAM_LO)
    # integer-valued params arrive rounded from the optimizer
    for i in (S.P_REDUCES, S.P_IO_SORT_MB, S.P_SORT_FACTOR,
              S.P_PARALLEL_COPIES, S.P_MAP_MEM_MB, S.P_RED_MEM_MB,
              S.P_SPLIT_MB):
        cfg[:, i] = np.round(cfg[:, i])
    cfg[:, S.P_COMPRESS] = np.round(cfg[:, S.P_COMPRESS])
    return cfg.astype(dtype)


def assert_matches_ref(cfg, consts, weights, block_n):
    rt_k, ph_k = cost_model_pallas(cfg, consts, weights, block_n=block_n)
    rt_r, ph_r = ref.cost_model_ref(cfg, consts, weights)
    np.testing.assert_allclose(np.asarray(ph_k), np.asarray(ph_r),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rt_k), np.asarray(rt_r),
                               rtol=1e-5, atol=1e-3)


class TestCostModelKernel:
    def test_basic_block(self):
        cfg = random_configs(S.BLOCK_N)
        assert_matches_ref(cfg, S.wordcount_consts(), S.default_weights(),
                           S.BLOCK_N)

    def test_multi_block(self):
        cfg = random_configs(4 * S.BLOCK_N)
        assert_matches_ref(cfg, S.wordcount_consts(), S.default_weights(),
                           S.BLOCK_N)

    def test_rejects_ragged_batch(self):
        cfg = random_configs(S.BLOCK_N + 1)
        with pytest.raises(ValueError, match="not a multiple"):
            cost_model_pallas(cfg, S.wordcount_consts(), S.default_weights())

    def test_runtime_positive(self):
        cfg = random_configs(2 * S.BLOCK_N)
        rt, ph = cost_model_pallas(cfg, S.wordcount_consts(),
                                   S.default_weights())
        assert np.all(np.asarray(rt) > 0)
        assert np.all(np.asarray(ph) >= 0)

    def test_more_sort_mb_never_more_spill_io(self):
        """Larger io.sort.mb => fewer (or equal) spills => map_io channel
        non-increasing, everything else fixed (paper Fig. 2 trend)."""
        base = random_configs(S.BLOCK_N)
        lo = base.copy(); lo[:, S.P_IO_SORT_MB] = 64.0
        hi = base.copy(); hi[:, S.P_IO_SORT_MB] = 1024.0
        c, w = S.wordcount_consts(), S.default_weights()
        _, ph_lo = cost_model_pallas(lo, c, w)
        _, ph_hi = cost_model_pallas(hi, c, w)
        assert np.all(np.asarray(ph_hi)[:, S.PH_MAP_IO]
                      <= np.asarray(ph_lo)[:, S.PH_MAP_IO] + 1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        blocks=st.integers(min_value=1, max_value=6),
        block_n=st.sampled_from([8, 32, 128]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_shapes(self, blocks, block_n, seed):
        rng = np.random.default_rng(seed)
        cfg = random_configs(blocks * block_n, rng)
        assert_matches_ref(cfg, S.wordcount_consts(), S.default_weights(),
                           block_n)

    @settings(max_examples=10, deadline=None)
    @given(
        input_mb=st.floats(min_value=64.0, max_value=4.0e6),
        nodes=st.integers(min_value=1, max_value=256),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_workloads(self, input_mb, nodes, seed):
        rng = np.random.default_rng(seed)
        cfg = random_configs(S.BLOCK_N, rng)
        consts = S.wordcount_consts(input_mb=input_mb, nodes=nodes)
        assert_matches_ref(cfg, consts, S.default_weights(), S.BLOCK_N)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_hypothesis_f64_configs_cast(self, seed):
        """f64 configs are accepted and cast; result matches the f32 ref."""
        rng = np.random.default_rng(seed)
        cfg64 = random_configs(S.BLOCK_N, rng, dtype=np.float64)
        from compile.model import cost_model
        rt, _ = cost_model(cfg64, S.wordcount_consts(), S.default_weights())
        rt_r, _ = ref.cost_model_ref(cfg64.astype(np.float32),
                                     S.wordcount_consts(),
                                     S.default_weights())
        np.testing.assert_allclose(np.asarray(rt), np.asarray(rt_r),
                                   rtol=1e-5, atol=1e-3)


class TestQuadraticKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        blocks=st.integers(min_value=1, max_value=4),
        block_n=st.sampled_from([8, 64, 128]),
        d=st.integers(min_value=1, max_value=12),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, blocks, block_n, d, seed):
        rng = np.random.default_rng(seed)
        n = blocks * block_n
        x = rng.standard_normal((n, d)).astype(np.float32)
        g = rng.standard_normal(d).astype(np.float32)
        a = rng.standard_normal((d, d)).astype(np.float32)
        h = (a + a.T) / 2.0
        c0 = np.array([rng.standard_normal()], np.float32)
        q_k = quadratic_pallas(x, g, h, c0, block_n=block_n)
        q_r = ref.quadratic_ref(x, g, h, c0[0])
        np.testing.assert_allclose(np.asarray(q_k), np.asarray(q_r),
                                   rtol=2e-5, atol=2e-5)

    def test_zero_padding_is_neutral(self):
        """Padding candidate dims with zeros must not change q (the rust
        optimizer pads low-dim problems up to QUAD_DIM)."""
        rng = np.random.default_rng(7)
        n, d, dpad = 128, 4, S.QUAD_DIM
        x = rng.standard_normal((n, d)).astype(np.float32)
        g = rng.standard_normal(d).astype(np.float32)
        a = rng.standard_normal((d, d)).astype(np.float32)
        h = (a + a.T) / 2.0
        c0 = np.array([0.5], np.float32)
        xp = np.zeros((n, dpad), np.float32); xp[:, :d] = x
        gp = np.zeros(dpad, np.float32); gp[:d] = g
        hp = np.zeros((dpad, dpad), np.float32); hp[:d, :d] = h
        q_pad = quadratic_pallas(xp, gp, hp, c0)
        q_ref = ref.quadratic_ref(x, g, h, c0[0])
        np.testing.assert_allclose(np.asarray(q_pad), np.asarray(q_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_rejects_ragged_batch(self):
        x = np.zeros((100, 4), np.float32)
        with pytest.raises(ValueError, match="not a multiple"):
            quadratic_pallas(x, np.zeros(4, np.float32),
                             np.zeros((4, 4), np.float32),
                             np.zeros(1, np.float32))

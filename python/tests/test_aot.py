"""AOT path: lowering to HLO text must succeed and produce parseable,
entry-computation-bearing modules of the expected arity."""

from compile import aot
from compile import spec as S


class TestLowering:
    def test_cost_model_lowers_to_hlo_text(self):
        text = aot.lower_cost_model(S.AOT_BATCH_SIZES[0])
        assert "ENTRY" in text
        assert "f32[128,10]" in text  # configs param
        assert "f32[16]" in text      # consts param

    def test_quadratic_lowers_to_hlo_text(self):
        text = aot.lower_quadratic(S.QUAD_BATCH, S.QUAD_DIM)
        assert "ENTRY" in text
        assert f"f32[{S.QUAD_BATCH},{S.QUAD_DIM}]" in text

    def test_no_custom_calls(self):
        """interpret=True pallas must lower to plain HLO the CPU PJRT
        client can run — no mosaic custom-calls allowed."""
        for text in (aot.lower_cost_model(128),
                     aot.lower_quadratic(S.QUAD_BATCH, S.QUAD_DIM)):
            assert "custom-call" not in text, "found custom-call in HLO"

"""L2 model-level tests: shapes, physics sanity of the cost surface
(the paper's Fig. 2 trends), and padding semantics."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import spec as S
from compile.model import cost_model, pad_batch


def grid_configs(reduces_vals, sortmb_vals):
    """Cross product over the two Fig.2 params with defaults elsewhere."""
    rows = []
    for r in reduces_vals:
        for s in sortmb_vals:
            cfg = np.array(
                [r, s, 10, 0.8, 5, 0.8, 1024, 1024, 0, 128], np.float32
            )
            rows.append(cfg)
    return np.stack(rows)


class TestCostSurface:
    def test_fig2_trend_larger_sortmb_helps_on_average(self):
        """Paper: larger io.sort.mb tends to reduce running time."""
        reduces = [8]
        cfgs = grid_configs(reduces, [32, 64, 128, 256, 512, 1024])
        cfgs = pad_batch(np.asarray(cfgs), S.BLOCK_N)
        rt, _ = cost_model(cfgs, S.wordcount_consts(), S.default_weights())
        rt = np.asarray(rt)[:6]
        assert rt[-1] <= rt[0], f"sort.mb=1024 not faster than 32: {rt}"

    def test_fig2_trend_more_reducers_help_until_waves(self):
        """More reduce parallelism lowers runtime until container waves
        kick in; with 16 nodes x 8 slots, 64 reducers are one wave."""
        cfgs = grid_configs([1, 2, 4, 8, 16, 32], [256])
        cfgs = pad_batch(np.asarray(cfgs), S.BLOCK_N)
        rt, _ = cost_model(cfgs, S.wordcount_consts(), S.default_weights())
        rt = np.asarray(rt)[:6]
        assert rt[5] < rt[0], f"32 reducers not faster than 1: {rt}"

    def test_wave_boundary_creates_jump(self):
        """Crossing a reduce-wave boundary must *increase* runtime — the
        source of the paper's 'huge fluctuations'."""
        consts = S.wordcount_consts(nodes=4)  # 4 nodes x 8 vcores = 32 slots
        cfgs = grid_configs([32, 33], [256])  # 33 reducers -> 2 waves
        cfgs = pad_batch(np.asarray(cfgs), S.BLOCK_N)
        rt, _ = cost_model(cfgs, consts, S.default_weights())
        rt = np.asarray(rt)
        assert rt[1] > rt[0]

    def test_phase_decomposition_sums(self):
        cfgs = pad_batch(grid_configs([8], [256]), S.BLOCK_N)
        rt, ph = cost_model(cfgs, S.wordcount_consts(), S.default_weights())
        manual = np.asarray(ph) @ S.default_weights()
        np.testing.assert_allclose(
            np.asarray(rt), manual.sum(-1), rtol=1e-5, atol=1e-3
        )


class TestPadBatch:
    def test_pad_identity(self):
        x = np.ones((128, 3), np.float32)
        assert pad_batch(x, 128) is x

    def test_pad_repeats_last_row(self):
        x = np.arange(6, dtype=np.float32).reshape(3, 2)
        out = np.asarray(pad_batch(x, 5))
        assert out.shape == (5, 2)
        np.testing.assert_array_equal(out[3], x[2])
        np.testing.assert_array_equal(out[4], x[2])

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=1, max_value=128))
    def test_padding_never_changes_leading_results(self, n):
        rng = np.random.default_rng(n)
        u = rng.random((n, S.N_PARAMS), np.float32)
        cfg = S.PARAM_LO + u * (S.PARAM_HI - S.PARAM_LO)
        padded = pad_batch(cfg, S.BLOCK_N)
        rt_p, _ = cost_model(np.asarray(padded), S.wordcount_consts(),
                             S.default_weights())
        # reference: evaluate the unpadded rows in a full block of copies
        full = np.repeat(cfg[:1], S.BLOCK_N, axis=0)
        full[:n] = cfg
        rt_f, _ = cost_model(full, S.wordcount_consts(), S.default_weights())
        np.testing.assert_allclose(np.asarray(rt_p)[:n],
                                   np.asarray(rt_f)[:n],
                                   rtol=1e-6, atol=1e-4)

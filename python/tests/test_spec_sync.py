"""Cross-language guard: the rust mirrors of spec.py (parameter order,
bounds, consts layout, calibration matrix) must stay in lockstep.  Parses
the rust sources directly so a drift fails the python suite too (the rust
side has the complementary check via the AOT artifacts)."""

import os
import re

import numpy as np

from compile import spec as S

ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def read(rel):
    with open(os.path.join(ROOT, rel)) as f:
        return f.read()


class TestParamTableSync:
    def setup_method(self):
        self.rust = read("rust/src/config/params.rs")

    def test_param_count_matches(self):
        m = re.search(r"pub const N_PARAMS: usize = (\d+);", self.rust)
        assert int(m.group(1)) == S.N_PARAMS

    def test_names_order_and_bounds_match(self):
        rows = re.findall(
            r'ParamMeta \{ index: (\w+), name: "([^"]+)", lo: ([\d.]+), '
            r"hi: ([\d.]+), integer: (\w+)", self.rust)
        assert len(rows) == S.N_PARAMS
        for i, (_, name, lo, hi, _integer) in enumerate(rows):
            assert name == S.PARAM_NAMES[i], f"param {i} name drift"
            assert float(lo) == S.PARAM_LO[i], f"{name} lo drift"
            assert float(hi) == S.PARAM_HI[i], f"{name} hi drift"

    def test_integerness_matches_test_generator(self):
        rows = [m[4] for m in re.findall(
            r'ParamMeta \{ index: (\w+), name: "([^"]+)", lo: ([\d.]+), '
            r"hi: ([\d.]+), integer: (\w+)", self.rust)]
        int_idx = {S.P_REDUCES, S.P_IO_SORT_MB, S.P_SORT_FACTOR,
                   S.P_PARALLEL_COPIES, S.P_MAP_MEM_MB, S.P_RED_MEM_MB,
                   S.P_SPLIT_MB, S.P_COMPRESS}
        for i, flag in enumerate(rows):
            assert (flag == "true") == (i in int_idx), f"param {i} integer drift"


class TestConstsLayoutSync:
    def test_to_consts_order(self):
        rust = read("rust/src/hadoop/mod.rs")
        body = rust.split("pub fn to_consts")[1].split("\n    }")[0]
        comments = re.findall(r"// (C_\w+)", body)
        expected = ["C_INPUT_MB", "C_MAP_SELECTIVITY", "C_CPU_PER_MB_MAP",
                    "C_CPU_PER_MB_RED", "C_NODES", "C_MEM_PER_NODE_MB",
                    "C_VCORES", "C_DISK_MBS", "C_NET_MBS", "C_COMPRESS_RATIO",
                    "C_OUTPUT_SELECTIVITY", "C_REPLICATION",
                    "C_TASK_OVERHEAD_S", "C_AM_OVERHEAD_S", "C_RECORD_KB",
                    "C_LOCALITY"]
        assert comments == expected
        for i, name in enumerate(expected):
            assert getattr(S, name) == i


class TestWeightsSync:
    def test_calibration_matrix_matches(self):
        rust = read("rust/src/hadoop/costmodel.rs")
        body = rust.split("pub fn default_weights")[1]
        pairs = re.findall(r"w\[(\w+)\]\[(\w+)\] = (-?[\d.]+);", body)
        w = np.eye(S.N_PHASES, dtype=np.float32)
        names = {"PH_MAP_CPU": S.PH_MAP_CPU, "PH_MAP_IO": S.PH_MAP_IO,
                 "PH_RED_CPU": S.PH_RED_CPU, "PH_RED_IO": S.PH_RED_IO}
        for a, b, v in pairs:
            w[names[a], names[b]] = float(v)
        np.testing.assert_array_equal(w, S.default_weights())

"""Cross-language guard: the rust mirrors of spec.py (parameter order,
bounds, consts layout, calibration matrix) must stay in lockstep.  Parses
the rust sources directly so a drift fails the python suite too (the rust
side has the complementary check via the AOT artifacts)."""

import os
import re

import numpy as np

from compile import spec as S

ROOT = os.path.join(os.path.dirname(__file__), "..", "..")


def read(rel):
    with open(os.path.join(ROOT, rel)) as f:
        return f.read()


class TestParamTableSync:
    """The builtin registry prefix in rust/src/config/space.rs
    (`builtin_defs()`) is the AOT-artifact row layout; it must stay in
    lockstep with spec.py's PARAM_NAMES / PARAM_LO / PARAM_HI."""

    def setup_method(self):
        self.rust = read("rust/src/config/space.rs")
        body = self.rust.split("pub fn builtin_defs")[1].split("\n}")[0]
        # one constructor call per builtin row:
        #   ParamDef::int("name", lo, hi, default)
        #   ParamDef::float("name", lo, hi, default)
        #   ParamDef::bool("name", default)
        self.rows = re.findall(
            r'ParamDef::(int|float|bool)\(\s*"([^"]+)"([^)]*)\)', body)

    def test_param_count_matches(self):
        m = re.search(r"pub const N_AOT_PARAMS: usize = (\d+);", self.rust)
        assert int(m.group(1)) == S.N_PARAMS
        assert len(self.rows) == S.N_PARAMS

    def _bounds(self, kind, args):
        if kind == "bool":
            return 0.0, 1.0
        nums = [float(x) for x in re.findall(r"[\d.]+", args)]
        return nums[0], nums[1]

    def test_names_order_and_bounds_match(self):
        for i, (kind, name, args) in enumerate(self.rows):
            assert name == S.PARAM_NAMES[i], f"param {i} name drift"
            lo, hi = self._bounds(kind, args)
            assert lo == S.PARAM_LO[i], f"{name} lo drift"
            assert hi == S.PARAM_HI[i], f"{name} hi drift"

    def test_integerness_matches_test_generator(self):
        int_idx = {S.P_REDUCES, S.P_IO_SORT_MB, S.P_SORT_FACTOR,
                   S.P_PARALLEL_COPIES, S.P_MAP_MEM_MB, S.P_RED_MEM_MB,
                   S.P_SPLIT_MB, S.P_COMPRESS}
        for i, (kind, name, _args) in enumerate(self.rows):
            discrete = kind in ("int", "bool")
            assert discrete == (i in int_idx), f"param {i} ({name}) integer drift"


class TestConstsLayoutSync:
    def test_to_consts_order(self):
        rust = read("rust/src/hadoop/mod.rs")
        body = rust.split("pub fn to_consts")[1].split("\n    }")[0]
        comments = re.findall(r"// (C_\w+)", body)
        expected = ["C_INPUT_MB", "C_MAP_SELECTIVITY", "C_CPU_PER_MB_MAP",
                    "C_CPU_PER_MB_RED", "C_NODES", "C_MEM_PER_NODE_MB",
                    "C_VCORES", "C_DISK_MBS", "C_NET_MBS", "C_COMPRESS_RATIO",
                    "C_OUTPUT_SELECTIVITY", "C_REPLICATION",
                    "C_TASK_OVERHEAD_S", "C_AM_OVERHEAD_S", "C_RECORD_KB",
                    "C_LOCALITY"]
        assert comments == expected
        for i, name in enumerate(expected):
            assert getattr(S, name) == i


class TestWeightsSync:
    def test_calibration_matrix_matches(self):
        rust = read("rust/src/hadoop/costmodel.rs")
        body = rust.split("pub fn default_weights")[1]
        pairs = re.findall(r"w\[(\w+)\]\[(\w+)\] = (-?[\d.]+);", body)
        w = np.eye(S.N_PHASES, dtype=np.float32)
        names = {"PH_MAP_CPU": S.PH_MAP_CPU, "PH_MAP_IO": S.PH_MAP_IO,
                 "PH_RED_CPU": S.PH_RED_CPU, "PH_RED_IO": S.PH_RED_IO}
        for a, b, v in pairs:
            w[names[a], names[b]] = float(v)
        np.testing.assert_array_equal(w, S.default_weights())

#!/usr/bin/env bash
# Serve daemon smoke: open N tuning sessions against a debug
# `catla serve`, drive them to completion over the line protocol, and
# assert a clean drain + shutdown — every session opens, reports
# done=true, closes with a best value, its history logs exist, the
# daemon answers no `err` lines and exits 0 on `shutdown`.
#
# Usage: scripts/serve_smoke.sh   (N=16 scripts/serve_smoke.sh for more)
set -euo pipefail
cd "$(dirname "$0")/.."

N="${N:-8}"
work="$(mktemp -d)"
trap 'rm -rf "$work"' EXIT

cargo build --quiet --bin catla

for i in $(seq 1 "$N"); do
  dir="$work/proj$i"
  ./target/debug/catla template --dir "$dir" --kind tuning --workload wordcount --input-mb 512 >/dev/null
  # small budget so the smoke stays fast
  printf 'optimizer=bobyqa\nbudget=6\nrepeats=1\nseed=7\n' > "$dir/tuning.properties"
done

{
  for i in $(seq 1 "$N"); do echo "open s$i $work/proj$i"; done
  echo "run"
  for i in $(seq 1 "$N"); do echo "status s$i"; done
  echo "stats"
  for i in $(seq 1 "$N"); do echo "close s$i"; done
  echo "shutdown"
} > "$work/script.txt"

out="$work/out.txt"
./target/debug/catla serve --threads 2 < "$work/script.txt" > "$out"

opens=$(grep -c '^ok open ' "$out" || true)
closes=$(grep -c '^ok close ' "$out" || true)
drained=$(grep -c '^ok status .* done=true' "$out" || true)
[ "$opens" -eq "$N" ] || { echo "expected $N 'ok open' lines, got $opens"; cat "$out"; exit 1; }
[ "$drained" -eq "$N" ] || { echo "expected $N drained sessions, got $drained"; cat "$out"; exit 1; }
[ "$closes" -eq "$N" ] || { echo "expected $N 'ok close' lines, got $closes"; cat "$out"; exit 1; }
grep -q '^ok shutdown$' "$out" || { echo "no clean shutdown reply"; cat "$out"; exit 1; }
if grep -q '^err ' "$out"; then echo "daemon reported errors:"; grep '^err ' "$out"; exit 1; fi

for i in $(seq 1 "$N"); do
  [ -s "$work/proj$i/history/tuning_log.csv" ] || { echo "proj$i: tuning log missing"; exit 1; }
  [ -s "$work/proj$i/history/summary.csv" ] || { echo "proj$i: summary row missing"; exit 1; }
done

echo "serve smoke ok: $N sessions opened, drained, closed; clean shutdown"

# ---- crash tolerance: a poisoned session fails alone -------------------
# A second daemon run with the hidden fault hook: every evaluation owned
# by session `bad` panics in the worker, forever. The session must
# exhaust its retry budget and land in the Failed terminal state while
# its sibling (a different project, so no shared cache entries) drains,
# closes and writes its logs untouched — and the daemon still answers a
# clean shutdown. The distinct input sizes keep the two sessions' memo
# keys disjoint, so the poison cannot leak through dedup.
for p in bad good; do
  dir="$work/poison_$p"
  if [ "$p" = bad ]; then mb=1024; else mb=512; fi
  ./target/debug/catla template --dir "$dir" --kind tuning --workload wordcount --input-mb "$mb" >/dev/null
  printf 'optimizer=bobyqa\nbudget=6\nrepeats=1\nseed=7\n' > "$dir/tuning.properties"
done

{
  echo "open bad $work/poison_bad"
  echo "open good $work/poison_good"
  echo "run"
  echo "status bad"
  echo "status good"
  echo "close good"
  echo "close bad"
  echo "shutdown"
} > "$work/poison_script.txt"

pout="$work/poison_out.txt"
./target/debug/catla serve --threads 2 --poison bad:999999 < "$work/poison_script.txt" > "$pout"

grep -q '^ok status bad .*done=true failed=' "$pout" \
  || { echo "poisoned session did not report Failed"; cat "$pout"; exit 1; }
grep '^ok status good ' "$pout" | grep -q 'done=true' \
  || { echo "sibling session did not drain"; cat "$pout"; exit 1; }
if grep '^ok status good ' "$pout" | grep -q 'failed='; then
  echo "sibling session was poisoned too"; cat "$pout"; exit 1
fi
grep -q '^ok close good ' "$pout" || { echo "sibling close failed"; cat "$pout"; exit 1; }
grep -q '^err session bad failed:' "$pout" \
  || { echo "close of the failed session should answer err"; cat "$pout"; exit 1; }
grep -q '^ok shutdown$' "$pout" || { echo "no clean shutdown after a failed session"; cat "$pout"; exit 1; }
[ -s "$work/poison_good/history/tuning_log.csv" ] || { echo "sibling tuning log missing"; exit 1; }

echo "serve smoke ok: poisoned session failed alone, sibling drained clean"

# ---- crash consistency: a kill -9 loop crawls to completion ------------
# The hidden `--crash-at <point>` hook aborts the process (SIGABRT —
# kill -9's deterministic in-process stand-in) at a registered
# durability point. Armed at `journal.after-append`, every incarnation
# replays the journal, evaluates exactly ONE new slice, checkpoints it,
# and dies — so a loop of kills must make one slice of progress per run,
# eventually complete (a fully-replayed session appends nothing, so the
# armed point never fires again), and leave history byte-identical to a
# daemon that was never killed.
for p in ref crash; do
  dir="$work/ckpt_$p"
  ./target/debug/catla template --dir "$dir" --kind tuning --workload wordcount --input-mb 512 >/dev/null
  printf 'optimizer=bobyqa\nbudget=6\nrepeats=1\nseed=7\n' > "$dir/tuning.properties"
done
session_script() { printf 'open s %s\nrun\nclose s\nshutdown\n' "$1"; }

session_script "$work/ckpt_ref" | ./target/debug/catla serve >/dev/null

kills=0
for i in $(seq 1 10); do
  if session_script "$work/ckpt_crash" | ./target/debug/catla serve --crash-at journal.after-append \
       >/dev/null 2>"$work/ckpt_err.txt"; then
    break
  fi
  kills=$((kills + 1))
  grep -q 'crash point "journal.after-append" hit' "$work/ckpt_err.txt" \
    || { echo "daemon died somewhere other than the armed point:"; cat "$work/ckpt_err.txt"; exit 1; }
done
[ "$kills" -ge 2 ] || { echo "crash hook fired only $kills time(s) — the loop tested nothing"; exit 1; }
for f in tuning_log.csv summary.csv; do
  cmp -s "$work/ckpt_ref/history/$f" "$work/ckpt_crash/history/$f" \
    || { echo "recovered $f differs from the uninterrupted reference"; exit 1; }
done
[ ! -e "$work/ckpt_crash/history/tuning_log.csv.journal" ] \
  || { echo "checkpoint journal survived a completed session"; exit 1; }

echo "serve smoke ok: $kills kills, one slice per incarnation, byte-identical recovery"

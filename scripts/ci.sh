#!/usr/bin/env bash
# CI gate: format check, clippy (-D warnings, the ask/tell core must stay
# lint-clean), release build, test suite. fmt/clippy are skipped with a
# notice when the toolchain component is not installed (offline images).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt not installed — skipped"
fi

echo "== clippy (optim::core and the rest of the lib, -D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --lib --all-targets -- -D warnings
else
    echo "clippy not installed — skipped"
fi

echo "== build =="
cargo build --release

echo "== test =="
cargo test -q

echo "CI OK"

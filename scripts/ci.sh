#!/usr/bin/env bash
# CI gate: format check, clippy (-D warnings, the ask/tell core must stay
# lint-clean), a pinned clippy-pedantic subset, the detlint
# determinism-and-unsafety gate (with its fixture self-test), release
# build, test suite, and a dependency-advisory audit. fmt/clippy/audit
# are skipped with a notice when the toolchain component is not
# installed (offline images); detlint always runs — it is part of this
# workspace and needs only cargo.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fmt =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt not installed — skipped"
fi

echo "== clippy (optim::core and the rest of the lib, -D warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --lib --all-targets -- -D warnings
    cargo clippy -p detlint --all-targets -- -D warnings
    # pinned pedantic subset over the production lib: exact float
    # comparison, hash-mutable map keys, and double-lookup map inserts
    # are determinism/correctness hazards here, not style
    cargo clippy --lib -- \
        -D clippy::float_cmp \
        -D clippy::mutable_key_type \
        -D clippy::map_entry
else
    echo "clippy not installed — skipped"
fi

echo "== detlint (determinism & unsafety gate) =="
cargo build --release -p detlint
./target/release/detlint rust/src
# self-test: the clean corpus must pass and every seeded violation must
# fail the gate — proof in every CI run that the gate can still fire
./target/release/detlint tools/detlint/fixtures/clean
if ./target/release/detlint tools/detlint/fixtures/violations >/dev/null 2>&1; then
    echo "detlint self-test FAILED: seeded violations passed the gate"
    exit 1
fi
cargo test -q -p detlint

echo "== build =="
cargo build --release

echo "== test =="
cargo test -q

echo "== audit (dependency advisories) =="
if cargo audit --version >/dev/null 2>&1; then
    # the workspace is dependency-free, so this is a tripwire for any
    # future dependency rather than a live surface today
    [ -f Cargo.lock ] || cargo generate-lockfile
    cargo audit
else
    echo "cargo-audit not installed — skipped"
fi

echo "CI OK"

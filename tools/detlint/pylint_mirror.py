#!/usr/bin/env python3
"""Python mirror of the detlint pass — `tools/detlint/src/lib.rs` is
authoritative. This mirror exists so rule changes can be validated on
hosts without a Rust toolchain:

    python3 tools/detlint/pylint_mirror.py rust/src      # lint a tree
    python3 tools/detlint/pylint_mirror.py --check-fixtures

`--check-fixtures` replays the same marker-parity contract as
`tests/fixtures.rs`: every `violations/` fixture must be flagged exactly
at its `//~v <rule>` markers (which sit on the line ABOVE the violation)
and every `clean/` fixture must pass. Keep the two implementations in
lock-step; the fixture corpus is the shared contract.
"""

import os
import sys

RULES = [
    "hash-collections",
    "ambient-entropy",
    "float-ord",
    "safety-comment",
    "allow-reason",
    "raw-fs-write",
]
CRITICAL_TREES = ("hadoop/", "optim/", "serve/", "config/")
ENTROPY_EXEMPT = ("util/bench.rs", "main.rs")
RAW_WRITE_TOKENS = ["fs::write", "File::create"]
ENTROPY_TOKENS = [
    "Instant::now",
    "SystemTime",
    "thread_rng",
    "std::env::",
    "env::var",
    "env::vars",
    "env::var_os",
    "env::args",
    "env::temp_dir",
    "env::current_dir",
]


def is_ident(c):
    return c.isascii() and (c.isalnum() or c == "_")


def raw_string_open(s, i):
    j = i
    if s[j] == "b":
        j += 1
    if j >= len(s) or s[j] != "r":
        return None
    j += 1
    hashes = 0
    while j < len(s) and s[j] == "#":
        hashes += 1
        j += 1
    if j < len(s) and s[j] == '"':
        return (hashes, j + 1 - i)
    return None


def char_literal_at(s, i):
    if i + 1 >= len(s):
        return False
    if s[i + 1] == "\\":
        return True
    return i + 2 < len(s) and s[i + 2] == "'"


def skip_char_literal(s, i):
    j = i + 1
    if j < len(s) and s[j] == "\\":
        j += 2  # backslash + the escaped character (possibly ' itself)
        while j < len(s) and s[j] != "'":
            j += 1
        return j + 1
    return i + 3


def split_source(src):
    """Per-line (code, comment) pairs with string/char contents blanked."""
    lines = []
    code, comment = [], []
    mode = "code"
    depth = 0
    hashes = 0
    i, n = 0, len(src)
    while i < n:
        c = src[i]
        if c == "\n":
            lines.append(("".join(code), "".join(comment)))
            code, comment = [], []
            i += 1
            continue
        if mode == "code":
            if c == "/" and src[i + 1 : i + 2] == "/":
                j = i + 2
                while j < n and src[j] != "\n":
                    comment.append(src[j])
                    j += 1
                comment.append(" ")
                i = j
            elif c == "/" and src[i + 1 : i + 2] == "*":
                mode, depth = "block", 1
                i += 2
            elif c == '"':
                code.append('"')
                mode = "str"
                i += 1
            elif c in "rb" and not (i > 0 and is_ident(src[i - 1])):
                opened = raw_string_open(src, i)
                if opened is not None:
                    hashes, skip = opened
                    code.append('r"')
                    mode = "rawstr"
                    i += skip
                elif c == "b" and src[i + 1 : i + 2] == '"':
                    code.append('b"')
                    mode = "str"
                    i += 2
                elif c == "b" and src[i + 1 : i + 2] == "'":
                    code.append("b''")
                    i = skip_char_literal(src, i + 1)
                else:
                    code.append(c)
                    i += 1
            elif c == "'":
                if char_literal_at(src, i):
                    code.append("''")
                    i = skip_char_literal(src, i)
                else:
                    code.append("'")  # a lifetime tick
                    i += 1
            else:
                code.append(c)
                i += 1
        elif mode == "block":
            if c == "/" and src[i + 1 : i + 2] == "*":
                depth += 1
                i += 2
            elif c == "*" and src[i + 1 : i + 2] == "/":
                depth -= 1
                if depth == 0:
                    mode = "code"
                i += 2
            else:
                comment.append(c)
                i += 1
        elif mode == "str":
            if c == "\\":
                # keep a backslash-newline un-consumed: line accounting
                i += 1 if src[i + 1 : i + 2] == "\n" else 2
            elif c == '"':
                code.append('"')
                mode = "code"
                i += 1
            else:
                i += 1
        else:  # rawstr
            if c == '"' and all(
                i + k < n and src[i + k] == "#" for k in range(1, hashes + 1)
            ):
                code.append('"')
                mode = "code"
                i += 1 + hashes
            else:
                i += 1
    if code or comment:
        lines.append(("".join(code), "".join(comment)))
    return lines


def test_mask(lines):
    mask = [False] * len(lines)
    i = 0
    while i < len(lines):
        if "#[cfg(test)]" not in lines[i][0]:
            i += 1
            continue
        depth, opened = 0, False
        j = i
        while j < len(lines):
            mask[j] = True
            stop = False
            for c in lines[j][0]:
                if c == "{":
                    depth += 1
                    opened = True
                elif c == "}":
                    depth -= 1
                    if opened and depth <= 0:
                        stop = True
                elif c == ";" and not opened:
                    stop = True
            if stop:
                break
            j += 1
        i = j + 1
    return mask


def has_token(s, pat):
    first = is_ident(pat[0])
    last = is_ident(pat[-1])
    start = 0
    while True:
        at = s.find(pat, start)
        if at < 0:
            return False
        end = at + len(pat)
        before = not first or at == 0 or not is_ident(s[at - 1])
        after = not last or end >= len(s) or not is_ident(s[end])
        if before and after:
            return True
        start = at + 1


def parse_allows(comment):
    out = []
    opener = "detlint: allow("
    start = 0
    while True:
        at = comment.find(opener, start)
        if at < 0:
            return out
        body_start = at + len(opener)
        close = comment.find(")", body_start)
        if close < 0:
            return out
        rules = [r.strip() for r in comment[body_start:close].split(",")]
        tail = comment[close + 1 :].lstrip()
        has_reason = tail.startswith("--") and tail[2:].strip() != ""
        out.append((rules, has_reason))
        start = close


def suppression(lines, idx, rule):
    best = "no"
    k = idx
    while True:
        for rules, has_reason in parse_allows(lines[k][1]):
            if rule in rules:
                if has_reason:
                    return "yes"
                best = "missing"
        if k == 0:
            break
        pcode, pcomment = lines[k - 1]
        if pcode.strip() or not pcomment.strip():
            break
        k -= 1
    return best


def safety_documented(lines, idx):
    if "SAFETY" in lines[idx][1]:
        return True
    k = idx
    while k > 0:
        pcode, pcomment = lines[k - 1]
        if pcode.strip() or not pcomment.strip():
            return False
        if "SAFETY" in pcomment:
            return True
        k -= 1
    return False


def lint_file(rel, src):
    rel = rel.replace("\\", "/")
    lines = split_source(src)
    tests = test_mask(lines)
    critical = any(rel.startswith(t) for t in CRITICAL_TREES)
    entropy_exempt = rel in ENTROPY_EXEMPT
    findings = []
    for idx, (code, comment) in enumerate(lines):
        if not code.strip():
            continue
        hits = []
        if critical and (has_token(code, "HashMap") or has_token(code, "HashSet")):
            hits.append("hash-collections")
        if not entropy_exempt and not tests[idx]:
            if any(has_token(code, p) for p in ENTROPY_TOKENS):
                hits.append("ambient-entropy")
        if ".partial_cmp" in code:
            hits.append("float-ord")
        if has_token(code, "unsafe") and not safety_documented(lines, idx):
            hits.append("safety-comment")
        if not rel.startswith("util/") and not tests[idx]:
            if any(has_token(code, p) for p in RAW_WRITE_TOKENS):
                hits.append("raw-fs-write")
        if critical and ("#[allow" in code or "#![allow" in code):
            if "reason" not in code and not comment.strip():
                hits.append("allow-reason")
        for rule in hits:
            s = suppression(lines, idx, rule)
            if s == "yes":
                continue
            suffix = " (suppression without a reason)" if s == "missing" else ""
            findings.append((idx + 1, rule, suffix))
    return findings


def rust_files(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for f in sorted(filenames):
            if f.endswith(".rs"):
                out.append(os.path.join(dirpath, f))
    return sorted(out)


def lint_root(root):
    findings = []
    is_dir = os.path.isdir(root)
    files = rust_files(root) if is_dir else [root]
    for path in files:
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        rel = os.path.relpath(path, root) if is_dir else os.path.basename(path)
        for line, rule, suffix in lint_file(rel, src):
            findings.append((path, line, rule, suffix))
    return len(files), findings


def check_fixtures(base):
    ok = True
    vroot = os.path.join(base, "fixtures", "violations")
    for path in rust_files(vroot):
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        rel = os.path.relpath(path, vroot)
        expected = set()
        for i, raw in enumerate(src.splitlines()):
            t = raw.strip()
            if t.startswith("//~v "):
                for r in t[len("//~v ") :].split(","):
                    expected.add((i + 2, r.strip()))
        got = {(line, rule) for line, rule, _ in lint_file(rel, src)}
        if got != expected:
            ok = False
            print(f"MISMATCH {rel}: got {sorted(got)} expected {sorted(expected)}")
    croot = os.path.join(base, "fixtures", "clean")
    for path in rust_files(croot):
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        rel = os.path.relpath(path, croot)
        got = lint_file(rel, src)
        if got:
            ok = False
            print(f"CLEAN FIXTURE FLAGGED {rel}: {got}")
    print("fixture parity:", "OK" if ok else "FAILED")
    return 0 if ok else 1


def main(argv):
    if argv and argv[0] == "--check-fixtures":
        return check_fixtures(os.path.dirname(os.path.abspath(__file__)))
    roots = argv or ["rust/src"]
    total_files, all_findings = 0, []
    for root in roots:
        files, findings = lint_root(root)
        total_files += files
        all_findings.extend(findings)
    all_findings.sort()
    for path, line, rule, suffix in all_findings:
        print(f"{path}:{line}: detlint({rule}){suffix}")
    print(
        f"detlint-mirror: {total_files} file(s), {len(all_findings)} finding(s)",
        file=sys.stderr,
    )
    return 1 if all_findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

//! Fixture-corpus self-test: every `violations/` fixture is flagged at
//! exactly the lines its `//~v <rule>` markers predict (markers sit on
//! the line ABOVE the violation), every `clean/` fixture passes, and the
//! allow-without-reason case fails with the dedicated message.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

fn fixtures(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(sub)
}

/// `(line, rule)` pairs predicted by the `//~v` markers in `src`.
fn expectations(src: &str) -> BTreeSet<(usize, String)> {
    let mut out = BTreeSet::new();
    for (idx, line) in src.lines().enumerate() {
        if let Some(rules) = line.trim().strip_prefix("//~v ") {
            for rule in rules.split(',') {
                out.insert((idx + 2, rule.trim().to_string()));
            }
        }
    }
    out
}

#[test]
fn violation_fixtures_are_flagged_at_expected_lines() {
    let root = fixtures("violations");
    let files = detlint::rust_files(&root).expect("walk violations/");
    assert!(!files.is_empty(), "violations/ fixture corpus is missing");
    let mut covered: BTreeSet<String> = BTreeSet::new();
    for path in &files {
        let src = std::fs::read_to_string(path).unwrap();
        let rel = path.strip_prefix(&root).unwrap().to_string_lossy().into_owned();
        let expected = expectations(&src);
        assert!(!expected.is_empty(), "{rel}: violation fixture without //~v markers");
        let got: BTreeSet<(usize, String)> = detlint::lint_file(&rel, &src, &detlint::all_rules())
            .into_iter()
            .map(|f| (f.line, f.rule.to_string()))
            .collect();
        assert_eq!(got, expected, "{rel}: findings do not match the //~v markers");
        covered.extend(expected.into_iter().map(|(_, rule)| rule));
    }
    let all: BTreeSet<String> = detlint::RULES.iter().map(|(n, _)| n.to_string()).collect();
    assert_eq!(covered, all, "violations/ must cover every rule");
}

#[test]
fn clean_fixtures_pass() {
    let root = fixtures("clean");
    let files = detlint::rust_files(&root).expect("walk clean/");
    assert!(!files.is_empty(), "clean/ fixture corpus is missing");
    for path in &files {
        let src = std::fs::read_to_string(path).unwrap();
        let rel = path.strip_prefix(&root).unwrap().to_string_lossy().into_owned();
        let findings = detlint::lint_file(&rel, &src, &detlint::all_rules());
        assert!(findings.is_empty(), "{rel}: clean fixture flagged: {findings:?}");
    }
}

#[test]
fn allow_without_reason_still_fails() {
    let path = fixtures("violations").join("serve").join("allow_no_reason.rs");
    let src = std::fs::read_to_string(&path).unwrap();
    let findings = detlint::lint_file("serve/allow_no_reason.rs", &src, &detlint::all_rules());
    assert_eq!(findings.len(), 1, "exactly the unreasoned allow should survive: {findings:?}");
    assert_eq!(findings[0].rule, "hash-collections");
    assert!(
        findings[0].message.contains("without a reason"),
        "missing-reason message expected, got: {}",
        findings[0].message
    );
}

#[test]
fn rule_toggling_scopes_the_scan() {
    let path = fixtures("violations").join("hadoop").join("wall_clock.rs");
    let src = std::fs::read_to_string(&path).unwrap();
    let only_floats = detlint::select_rules("float-ord").unwrap();
    assert!(detlint::lint_file("hadoop/wall_clock.rs", &src, &only_floats).is_empty());
    let only_entropy = detlint::select_rules("ambient-entropy").unwrap();
    assert_eq!(detlint::lint_file("hadoop/wall_clock.rs", &src, &only_entropy).len(), 4);
}

//! detlint — the determinism-and-unsafety static-analysis gate.
//!
//! Catla's value over prior tuners is a *transparent, trustworthy*
//! implementation: optimizer comparisons are meaningful only because
//! eval sequences, tuning logs and `TuningOutcome`s replay
//! bit-identically under a fixed seed. The byte-identity test suites pin
//! that contract dynamically — but only for the interleavings someone
//! wrote down. This crate enforces the contract's *preconditions*
//! statically over `rust/src/**`, as hard CI errors with `file:line`
//! diagnostics:
//!
//! - `hash-collections` — no `HashMap`/`HashSet` in the four
//!   determinism-critical trees (`hadoop/`, `optim/`, `serve/`,
//!   `config/`): hash-iteration order is randomized per process and
//!   leaks into eval sequences the moment anything iterates.
//! - `ambient-entropy` — no wall clock or ambient entropy
//!   (`Instant::now`, `SystemTime`, `thread_rng`, `std::env` reads)
//!   outside `util/bench.rs` and `main.rs`. `#[cfg(test)]` items are
//!   exempt: test scaffolding may use temp dirs and env overrides
//!   without perturbing production behavior.
//! - `float-ord` — no `.partial_cmp(..)` on floats (`sort_by` closures,
//!   `.unwrap()` chains panic on NaN and under-order): route through
//!   `f64::total_cmp` / `util::ord::TotalF64`.
//! - `safety-comment` — every `unsafe` block, impl and fn carries a
//!   `// SAFETY:` comment stating the aliasing/lifetime argument.
//! - `allow-reason` — no `#[allow(..)]` without a written reason in the
//!   four determinism-critical trees.
//! - `raw-fs-write` — no `std::fs::write` / `File::create` outside
//!   `util/`: a raw write torn by a crash leaves a half-file the
//!   recovery path then has to distrust. Persistence goes through
//!   `util::durable` (atomic replace or CRC-framed append).
//!
//! Suppression: append `// detlint: allow(<rule>) -- <reason>` on the
//! offending line, or on a comment line directly above it. The reason
//! after `--` is mandatory — an allow without one still fails the gate.
//!
//! No `syn`, no dependencies: the workspace is dependency-free by design
//! (offline image), so the analysis is a small hand-rolled lexer
//! (comments, strings, char literals vs lifetimes, raw strings) plus
//! whole-token rules over the comment-stripped source. A Python mirror
//! of the same pass (`pylint_mirror.py`, same directory) exists so rule
//! changes can be validated on hosts without a Rust toolchain;
//! `src/lib.rs` is authoritative.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Every rule the gate knows, with a one-line summary (`--list-rules`).
pub const RULES: &[(&str, &str)] = &[
    ("hash-collections", "no HashMap/HashSet in hadoop/, optim/, serve/, config/"),
    ("ambient-entropy", "no wall clock or ambient entropy outside util/bench.rs and main.rs"),
    ("float-ord", "no .partial_cmp on floats — use total_cmp / util::ord::TotalF64"),
    ("safety-comment", "every unsafe block/impl/fn carries a // SAFETY: comment"),
    ("allow-reason", "no #[allow(..)] without a reason in the determinism-critical trees"),
    ("raw-fs-write", "no std::fs::write / File::create outside util/ — use util::durable"),
];

/// Module trees (paths relative to the scan root) where
/// `hash-collections` and `allow-reason` apply.
const CRITICAL_TREES: &[&str] = &["hadoop/", "optim/", "serve/", "config/"];

/// Files exempt from `ambient-entropy`: the bench harness owns the wall
/// clock, the CLI entry owns argv/env.
const ENTROPY_EXEMPT: &[&str] = &["util/bench.rs", "main.rs"];

/// Whole-token patterns the `raw-fs-write` rule bans outside `util/`
/// (where the durable-I/O primitives themselves live).
const RAW_WRITE_TOKENS: &[&str] = &["fs::write", "File::create"];

/// Whole-token patterns the `ambient-entropy` rule bans.
const ENTROPY_TOKENS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "thread_rng",
    "std::env::",
    "env::var",
    "env::vars",
    "env::var_os",
    "env::args",
    "env::temp_dir",
    "env::current_dir",
];

/// One diagnostic: a rule violated at `file:line`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: detlint({}): {}", self.file, self.line, self.rule, self.message)
    }
}

/// What [`lint_root`] scanned and found.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub files: usize,
    pub findings: Vec<Finding>,
}

/// One logical source line: executable code with comment text split off
/// and string/char-literal *contents* blanked (delimiters kept), so rule
/// patterns can never match inside comments or literals.
#[derive(Clone, Debug, Default)]
pub struct SourceLine {
    pub code: String,
    pub comment: String,
}

enum Mode {
    Code,
    Block(usize),
    Str,
    RawStr(usize),
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_ascii_alphanumeric() || chars[i - 1] == '_')
}

/// Returns `(hash_count, chars_consumed)` when `r"`, `r#"`, `br#"`, …
/// opens a raw string at `i`.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if j >= chars.len() || chars[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < chars.len() && chars[j] == '"' {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Does the `"` at `i` close a raw string delimited by `hashes` hashes?
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| i + k < chars.len() && chars[i + k] == '#')
}

/// Distinguish a char literal (`'x'`, `'\n'`, `'\u{1F600}'`) from a
/// lifetime (`'a`, `'static`) at the `'` at `i`.
fn char_literal_at(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(_) => chars.get(i + 2) == Some(&'\''),
        None => false,
    }
}

/// Index just past the closing quote of the char literal opening at `i`.
fn skip_char_literal(chars: &[char], i: usize) -> usize {
    let mut j = i + 1;
    if chars.get(j) == Some(&'\\') {
        j += 2; // backslash + the escaped character (possibly `'` itself)
        while j < chars.len() && chars[j] != '\'' {
            j += 1; // multi-char escape bodies: \u{..}, \x41
        }
        j + 1
    } else {
        i + 3
    }
}

/// Split source into per-line (code, comment) pairs. Handles line and
/// nested block comments, normal/byte/raw strings (multi-line included),
/// and char literals vs lifetimes. Line numbers are preserved exactly.
pub fn split_source(src: &str) -> Vec<SourceLine> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut cur = SourceLine::default();
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    let mut j = i + 2;
                    while j < n && chars[j] != '\n' {
                        cur.comment.push(chars[j]);
                        j += 1;
                    }
                    cur.comment.push(' ');
                    i = j;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::Block(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    if let Some((hashes, skip)) = raw_string_open(&chars, i) {
                        cur.code.push_str("r\"");
                        mode = Mode::RawStr(hashes);
                        i += skip;
                    } else if c == 'b' && chars.get(i + 1) == Some(&'"') {
                        cur.code.push_str("b\"");
                        mode = Mode::Str;
                        i += 2;
                    } else if c == 'b' && chars.get(i + 1) == Some(&'\'') {
                        cur.code.push_str("b''");
                        i = skip_char_literal(&chars, i + 1);
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    if char_literal_at(&chars, i) {
                        cur.code.push_str("''");
                        i = skip_char_literal(&chars, i);
                    } else {
                        // a lifetime tick — keep it, scanning continues
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            Mode::Block(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::Block(depth + 1);
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // keep a `\` before a newline un-consumed so line
                    // accounting stays exact (string continuations)
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    cur.code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    cur.code.push('"');
                    mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Lines belonging to `#[cfg(test)]`-gated items: from the attribute to
/// the close of the item's brace block (or its `;` for braceless items).
pub fn test_mask(lines: &[SourceLine]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            mask[j] = true;
            let mut stop = false;
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth <= 0 {
                            stop = true;
                        }
                    }
                    ';' if !opened => stop = true,
                    _ => {}
                }
            }
            if stop {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// `pat` occurs in `s` as a whole token: where the pattern starts or
/// ends with an identifier character, it must not extend into a longer
/// identifier on that side (so `Instant::now` never matches inside
/// `Instantiate`, but `std::env::` may be followed by a name).
pub fn has_token(s: &str, pat: &str) -> bool {
    let bytes = s.as_bytes();
    let pb = pat.as_bytes();
    let first_ident = is_ident_byte(pb[0]);
    let last_ident = is_ident_byte(pb[pb.len() - 1]);
    let mut from = 0;
    while let Some(off) = s[from..].find(pat) {
        let at = from + off;
        let end = at + pat.len();
        let before = !first_ident || at == 0 || !is_ident_byte(bytes[at - 1]);
        let after = !last_ident || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before && after {
            return true;
        }
        from = at + 1;
    }
    false
}

struct Allow {
    rules: Vec<String>,
    has_reason: bool,
}

/// Parse every `detlint: allow(<rules>) -- <reason>` in a comment.
fn parse_allows(comment: &str) -> Vec<Allow> {
    const OPEN: &str = "detlint: allow(";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(off) = comment[from..].find(OPEN) {
        let start = from + off + OPEN.len();
        let rest = &comment[start..];
        let close = match rest.find(')') {
            Some(c) => c,
            None => break,
        };
        let rules = rest[..close].split(',').map(|r| r.trim().to_string()).collect();
        let tail = rest[close + 1..].trim_start();
        let has_reason = tail.strip_prefix("--").is_some_and(|r| !r.trim().is_empty());
        out.push(Allow { rules, has_reason });
        from = start + close;
    }
    out
}

enum Suppress {
    No,
    Yes,
    MissingReason,
}

/// Is the finding for `rule` at line `idx` suppressed by an allow
/// comment on the line itself or the comment block directly above?
fn suppression(lines: &[SourceLine], idx: usize, rule: &str) -> Suppress {
    let mut best = Suppress::No;
    let mut k = idx;
    loop {
        for a in parse_allows(&lines[k].comment) {
            if a.rules.iter().any(|r| r == rule) {
                if a.has_reason {
                    return Suppress::Yes;
                }
                best = Suppress::MissingReason;
            }
        }
        if k == 0 {
            break;
        }
        let prev = &lines[k - 1];
        if !prev.code.trim().is_empty() || prev.comment.trim().is_empty() {
            break;
        }
        k -= 1;
    }
    best
}

/// Does the `unsafe` at line `idx` carry a SAFETY comment — trailing on
/// the line, or anywhere in the contiguous comment block directly above?
fn safety_documented(lines: &[SourceLine], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY") {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        let prev = &lines[k - 1];
        if !prev.code.trim().is_empty() || prev.comment.trim().is_empty() {
            return false;
        }
        if prev.comment.contains("SAFETY") {
            return true;
        }
        k -= 1;
    }
    false
}

/// Does a `#[allow(..)]` line carry a reason (trailing comment or an
/// in-attribute `reason = ".."`)?
fn allow_attr_justified(line: &SourceLine) -> bool {
    line.code.contains("reason") || !line.comment.trim().is_empty()
}

/// Lint one file. `rel_path` is the path relative to the scan root —
/// tree-scoped rules (`hash-collections`, `allow-reason`) and file
/// exemptions (`ambient-entropy`) key off it.
pub fn lint_file(rel_path: &str, src: &str, enabled: &BTreeSet<&'static str>) -> Vec<Finding> {
    let rel = rel_path.replace('\\', "/");
    let lines = split_source(src);
    let tests = test_mask(&lines);
    let critical = CRITICAL_TREES.iter().any(|t| rel.starts_with(t));
    let entropy_exempt = ENTROPY_EXEMPT.iter().any(|f| rel == *f);
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        if code.trim().is_empty() {
            continue;
        }
        let mut hits: Vec<(&'static str, String)> = Vec::new();
        if enabled.contains("hash-collections") && critical {
            for ty in ["HashMap", "HashSet"] {
                if has_token(code, ty) {
                    hits.push((
                        "hash-collections",
                        format!(
                            "`{ty}` in a determinism-critical tree: hash-iteration order is \
                             per-process random — use BTreeMap/BTreeSet or an index-linked \
                             structure"
                        ),
                    ));
                    break;
                }
            }
        }
        if enabled.contains("ambient-entropy") && !entropy_exempt && !tests[idx] {
            for pat in ENTROPY_TOKENS {
                if has_token(code, pat) {
                    hits.push((
                        "ambient-entropy",
                        format!(
                            "`{pat}`: wall clock / ambient entropy is banned outside \
                             util/bench.rs and main.rs — thread explicit seeds or \
                             configuration through instead"
                        ),
                    ));
                    break;
                }
            }
        }
        if enabled.contains("float-ord") && code.contains(".partial_cmp") {
            hits.push((
                "float-ord",
                "`.partial_cmp(..)` panics on NaN and under-orders floats: route through \
                 f64::total_cmp or util::ord::TotalF64"
                    .to_string(),
            ));
        }
        if enabled.contains("safety-comment")
            && has_token(code, "unsafe")
            && !safety_documented(&lines, idx)
        {
            hits.push((
                "safety-comment",
                "`unsafe` without a `// SAFETY:` comment stating the aliasing/lifetime argument"
                    .to_string(),
            ));
        }
        if enabled.contains("raw-fs-write") && !rel.starts_with("util/") && !tests[idx] {
            for pat in RAW_WRITE_TOKENS {
                if has_token(code, pat) {
                    hits.push((
                        "raw-fs-write",
                        format!(
                            "`{pat}`: a raw write torn by a crash leaves a half-file — use \
                             util::durable::atomic_write (replace) or append_bytes/append_framed \
                             (append-only)"
                        ),
                    ));
                    break;
                }
            }
        }
        if enabled.contains("allow-reason")
            && critical
            && (code.contains("#[allow") || code.contains("#![allow"))
            && !allow_attr_justified(line)
        {
            hits.push((
                "allow-reason",
                "#[allow(..)] without a reason: append `// <why>` on the line (or use \
                 `reason = \"..\"`)"
                    .to_string(),
            ));
        }
        for (rule, message) in hits {
            match suppression(&lines, idx, rule) {
                Suppress::Yes => {}
                Suppress::MissingReason => out.push(Finding {
                    file: rel.clone(),
                    line: idx + 1,
                    rule,
                    message: format!(
                        "suppression without a reason — write `// detlint: allow({rule}) -- <why>`"
                    ),
                }),
                Suppress::No => out.push(Finding { file: rel.clone(), line: idx + 1, rule, message }),
            }
        }
    }
    out
}

/// All `.rs` files under `root`, sorted for deterministic diagnostics.
pub fn rust_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    fn collect(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        for entry in fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_dir() {
                collect(&path, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    collect(root, &mut out)?;
    out.sort();
    Ok(out)
}

/// Lint a scan root (a directory tree, or a single file for spot
/// checks). Findings report root-joined paths; rule scoping uses paths
/// relative to `root`.
pub fn lint_root(root: &Path, enabled: &BTreeSet<&'static str>) -> io::Result<LintReport> {
    let files = if root.is_file() { vec![root.to_path_buf()] } else { rust_files(root)? };
    let mut report = LintReport { files: files.len(), findings: Vec::new() };
    for path in &files {
        let src = fs::read_to_string(path)?;
        let rel = match path.strip_prefix(root) {
            Ok(r) if !r.as_os_str().is_empty() => r.to_string_lossy().into_owned(),
            _ => path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        };
        for mut f in lint_file(&rel, &src, enabled) {
            f.file = path.to_string_lossy().into_owned();
            report.findings.push(f);
        }
    }
    Ok(report)
}

/// Every rule, enabled.
pub fn all_rules() -> BTreeSet<&'static str> {
    RULES.iter().map(|(n, _)| *n).collect()
}

/// Resolve a comma-separated rule list against [`RULES`].
pub fn select_rules(list: &str) -> Result<BTreeSet<&'static str>, String> {
    let mut out = BTreeSet::new();
    for name in list.split(',') {
        let name = name.trim();
        match RULES.iter().find(|(n, _)| *n == name) {
            Some((n, _)) => {
                out.insert(*n);
            }
            None => return Err(format!("unknown rule `{name}` (see --list-rules)")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, src: &str) -> Vec<Finding> {
        lint_file(rel, src, &all_rules())
    }

    fn rules_at(findings: &[Finding]) -> Vec<(usize, &'static str)> {
        findings.iter().map(|f| (f.line, f.rule)).collect()
    }

    #[test]
    fn lexer_splits_comments_strings_and_lifetimes() {
        let src = "let a = \"HashMap // not a comment\"; // trailing HashMap\n\
                   let b: Vec<'a> = v; let c = 'x'; let d = '\\'';\n\
                   /* block HashMap\n spans lines */ let e = r#\"raw \" HashSet\"#;\n";
        let lines = split_source(src);
        assert_eq!(lines.len(), 4);
        assert!(!lines[0].code.contains("HashMap"), "string content leaked into code");
        assert!(lines[0].comment.contains("HashMap"), "line comment lost");
        assert!(lines[1].code.contains("Vec<'a>"), "lifetime mangled: {}", lines[1].code);
        assert!(!lines[1].code.contains('x'), "char literal content leaked");
        assert!(lines[2].comment.contains("block HashMap"));
        assert!(lines[3].comment.contains("spans lines"));
        assert!(!lines[3].code.contains("HashSet"), "raw string content leaked");
        assert!(lines[3].code.contains("let e"));
    }

    #[test]
    fn lexer_handles_nested_block_comments() {
        let lines = split_source("/* outer /* inner */ still comment */ let x = 1;\n");
        assert!(lines[0].code.contains("let x = 1"));
        assert!(lines[0].comment.contains("inner"));
    }

    #[test]
    fn hash_collections_only_in_critical_trees() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_at(&lint("optim/x.rs", src)), vec![(1, "hash-collections")]);
        assert_eq!(rules_at(&lint("serve/x.rs", src)), vec![(1, "hash-collections")]);
        assert!(lint("util/x.rs", src).is_empty(), "util/ is not a scoped tree");
        assert!(lint("optim/x.rs", "let m = BTreeMap::new();\n").is_empty());
    }

    #[test]
    fn token_boundaries_do_not_false_positive() {
        assert!(lint("optim/x.rs", "struct MyHashMapLike;\n").is_empty());
        assert!(lint("catla/x.rs", "/// Instantiate a fresh optimizer.\nfn f() {}\n").is_empty());
        assert!(!lint("catla/x.rs", "let t = Instant::now();\n").is_empty());
    }

    #[test]
    fn ambient_entropy_exemptions() {
        let src = "let t = Instant::now();\nlet v = std::env::var(\"X\");\n";
        assert_eq!(lint("util/bench.rs", src).len(), 0);
        assert_eq!(lint("main.rs", src).len(), 0);
        assert_eq!(lint("hadoop/x.rs", src).len(), 2);
    }

    #[test]
    fn cfg_test_items_are_exempt_from_entropy() {
        let src = "fn prod() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn t() { let d = std::env::temp_dir(); }\n\
                   }\n";
        assert!(lint("catla/x.rs", src).is_empty());
        let braceless = "#[cfg(test)]\nuse foo::bar;\nlet t = Instant::now();\n";
        assert_eq!(rules_at(&lint("catla/x.rs", braceless)), vec![(3, "ambient-entropy")]);
    }

    #[test]
    fn float_ord_flags_partial_cmp_calls_not_definitions() {
        let bad = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert_eq!(rules_at(&lint("util/x.rs", bad)), vec![(1, "float-ord")]);
        let def = "fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n\
                   Some(self.cmp(other))\n}\n";
        assert!(lint("util/x.rs", def).is_empty());
        assert!(lint("util/x.rs", "v.sort_by(|a, b| a.total_cmp(b));\n").is_empty());
    }

    #[test]
    fn safety_comments_satisfy_the_unsafe_rule() {
        let bad = "let x = unsafe { *p };\n";
        assert_eq!(rules_at(&lint("util/x.rs", bad)), vec![(1, "safety-comment")]);
        let above = "// SAFETY: p is valid for the whole call\nlet x = unsafe { *p };\n";
        assert!(lint("util/x.rs", above).is_empty());
        let trailing = "let x = unsafe { *p }; // SAFETY: exclusive owner\n";
        assert!(lint("util/x.rs", trailing).is_empty());
        let gap = "// SAFETY: stale\nfn f() {}\nlet x = unsafe { *p };\n";
        assert_eq!(rules_at(&lint("util/x.rs", gap)), vec![(3, "safety-comment")]);
    }

    #[test]
    fn allow_attrs_need_reasons_in_critical_trees() {
        let bare = "#[allow(dead_code)]\nfn f() {}\n";
        assert_eq!(rules_at(&lint("config/x.rs", bare)), vec![(1, "allow-reason")]);
        assert!(lint("catla/x.rs", bare).is_empty(), "catla/ is not a scoped tree");
        let justified = "#[allow(dead_code)] // exercised via the line protocol\nfn f() {}\n";
        assert!(lint("config/x.rs", justified).is_empty());
    }

    #[test]
    fn allow_comments_suppress_with_a_reason_only() {
        let with = "use std::collections::HashMap; // detlint: allow(hash-collections) -- \
                    never iterated, keyed lookups only\n";
        assert!(lint("serve/x.rs", with).is_empty());
        let above = "// detlint: allow(hash-collections) -- never iterated\n\
                     use std::collections::HashMap;\n";
        assert!(lint("serve/x.rs", above).is_empty());
        let without = "use std::collections::HashMap; // detlint: allow(hash-collections)\n";
        let f = lint("serve/x.rs", without);
        assert_eq!(rules_at(&f), vec![(1, "hash-collections")]);
        assert!(f[0].message.contains("without a reason"), "{}", f[0].message);
        let wrong_rule = "use std::collections::HashMap; // detlint: allow(float-ord) -- no\n";
        assert_eq!(rules_at(&lint("serve/x.rs", wrong_rule)), vec![(1, "hash-collections")]);
    }

    #[test]
    fn raw_fs_write_banned_outside_util_except_tests() {
        let src = "std::fs::write(&path, text)?;\n";
        assert_eq!(rules_at(&lint("catla/x.rs", src)), vec![(1, "raw-fs-write")]);
        assert_eq!(rules_at(&lint("main.rs", src)), vec![(1, "raw-fs-write")]);
        assert!(lint("util/durable.rs", "let f = File::create(&tmp)?;\n").is_empty());
        let test_only = "#[cfg(test)]\nmod tests {\n fn t() { std::fs::write(&p, b\"x\").unwrap(); }\n}\n";
        assert!(lint("catla/x.rs", test_only).is_empty());
        let allowed = "std::fs::write(&path, text)?; // detlint: allow(raw-fs-write) -- \
                       scratch file outside any recovery path\n";
        assert!(lint("catla/x.rs", allowed).is_empty());
        assert!(lint("catla/x.rs", "fn rewrite_all(&self) {}\n").is_empty(), "token boundary");
    }

    #[test]
    fn select_rules_round_trips_and_rejects_unknown() {
        let sel = select_rules("float-ord, safety-comment").unwrap();
        assert_eq!(sel.len(), 2);
        assert!(select_rules("no-such-rule").is_err());
        assert_eq!(all_rules().len(), RULES.len());
    }
}

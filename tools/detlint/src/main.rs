//! CLI for the detlint gate. Exit status: 0 clean, 1 findings, 2 usage.
//!
//! ```text
//! detlint [--rules <r1,r2,..>] [--list-rules] [ROOT ...]
//! ```
//!
//! Each ROOT is a directory tree (or single file) scanned for `*.rs`;
//! the default is `rust/src`. Rule scoping (critical trees, entropy
//! exemptions) keys off paths relative to each ROOT, which is why CI
//! invokes it as `detlint rust/src` from the repo root.

use std::path::Path;
use std::process::ExitCode;

fn usage() {
    eprintln!("usage: detlint [--rules <r1,r2,..>] [--list-rules] [ROOT ...]");
    eprintln!("       default ROOT: rust/src");
}

fn main() -> ExitCode {
    let mut enabled = detlint::all_rules();
    let mut roots: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--help" | "-h" => {
                usage();
                return ExitCode::SUCCESS;
            }
            "--list-rules" => {
                for (name, summary) in detlint::RULES {
                    println!("{name:17} {summary}");
                }
                return ExitCode::SUCCESS;
            }
            "--rules" => {
                let Some(list) = args.next() else {
                    eprintln!("detlint: --rules needs a comma-separated rule list");
                    return ExitCode::from(2);
                };
                match detlint::select_rules(&list) {
                    Ok(sel) => enabled = sel,
                    Err(e) => {
                        eprintln!("detlint: {e}");
                        return ExitCode::from(2);
                    }
                }
            }
            other if other.starts_with('-') => {
                eprintln!("detlint: unknown flag `{other}`");
                usage();
                return ExitCode::from(2);
            }
            other => roots.push(other.to_string()),
        }
    }
    if roots.is_empty() {
        roots.push("rust/src".to_string());
    }

    let mut files = 0usize;
    let mut findings = Vec::new();
    for root in &roots {
        match detlint::lint_root(Path::new(root), &enabled) {
            Ok(report) => {
                files += report.files;
                findings.extend(report.findings);
            }
            Err(e) => {
                eprintln!("detlint: {root}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    for f in &findings {
        println!("{f}");
    }
    eprintln!("detlint: {} file(s) scanned, {} finding(s)", files, findings.len());
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

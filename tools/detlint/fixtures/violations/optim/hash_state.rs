//! Bad case for `hash-collections`: hash-keyed state in a
//! determinism-critical tree. Iteration order is per-process random.

//~v hash-collections
use std::collections::HashMap;
//~v hash-collections
use std::collections::HashSet;

pub struct HashState {
    //~v hash-collections
    pub done: HashSet<u64>,
    //~v hash-collections
    pub scores: HashMap<u64, f64>,
}

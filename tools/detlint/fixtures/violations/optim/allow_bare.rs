//! Bad case for `allow-reason`: a bare `#[allow(..)]` in a
//! determinism-critical tree.

//~v allow-reason
#[allow(dead_code)]
fn helper() {}

//! Bad case for `safety-comment`: unsafe without a stated
//! aliasing/lifetime argument.

pub struct Raw(*mut u8);

//~v safety-comment
unsafe impl Send for Raw {}

pub fn read(r: &Raw) -> u8 {
    //~v safety-comment
    unsafe { *r.0 }
}

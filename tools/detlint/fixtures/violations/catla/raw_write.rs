//! Bad case for `raw-fs-write`: persistence code writing files raw —
//! a crash mid-call leaves a torn half-file the recovery path then has
//! to distrust. The rule applies everywhere outside `util/`, not just
//! the determinism-critical trees.

use std::path::Path;

pub fn persist(path: &Path, text: &str) -> std::io::Result<()> {
    //~v raw-fs-write
    std::fs::write(path, text)?;
    //~v raw-fs-write
    let _f = std::fs::File::create(path.with_extension("bak"))?;
    Ok(())
}

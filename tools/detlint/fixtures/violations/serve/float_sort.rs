//! Bad case for `float-ord`: a partial order over floats — panics on
//! NaN and under-orders.

pub fn best(xs: &mut [(f64, u32)]) -> u32 {
    //~v float-ord
    xs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    xs[0].1
}

//! Bad case for allow-comment suppression: the allow names the rule but
//! omits the mandatory `-- <reason>` tail, so the finding survives (with
//! the dedicated missing-reason message).

//~v hash-collections
use std::collections::HashMap; // detlint: allow(hash-collections)

pub fn size_of_index(ix: &std::collections::BTreeMap<String, usize>) -> usize {
    ix.len()
}

//! Bad case for `ambient-entropy`: wall clock and ambient reads in
//! production simulator code.

pub fn stamp() -> u128 {
    //~v ambient-entropy
    let t = std::time::Instant::now();
    //~v ambient-entropy
    let _epoch = std::time::SystemTime::now();
    //~v ambient-entropy
    let tweak = std::env::var("CATLA_TWEAK").unwrap_or_default();
    //~v ambient-entropy
    let r: u64 = rand::thread_rng().gen();
    t.elapsed().as_nanos() + tweak.len() as u128 + u128::from(r)
}

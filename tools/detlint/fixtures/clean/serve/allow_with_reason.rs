//! Good case for `allow-reason`: the attribute carries a written reason.

#[allow(dead_code)] // exercised only through the line-protocol tests
fn drain_token(buf: &str) -> &str {
    buf.trim()
}

//! Good case for `hash-collections`: ordered structures by default, and
//! the one hash-keyed map carries a reasoned allow.

use std::collections::{BTreeMap, BTreeSet};

// detlint: allow(hash-collections) -- interner is lookup-only; nothing
// ever iterates it, so hash order cannot leak into eval sequences
use std::collections::HashMap;

pub struct OrderedState {
    pub visited: BTreeSet<u64>,
    pub scores: BTreeMap<u64, f64>,
    interned: HashMap<String, u32>, // detlint: allow(hash-collections) -- lookup-only interner
}

impl OrderedState {
    pub fn record(&mut self, key: u64, score: f64) {
        self.visited.insert(key);
        self.scores.insert(key, score);
    }

    pub fn intern(&mut self, name: &str) -> u32 {
        let next = self.interned.len() as u32;
        *self.interned.entry(name.to_string()).or_insert(next)
    }
}

//! Good case for `ambient-entropy`: all randomness flows from an
//! explicit caller-provided seed, all time is simulated virtual time.

pub struct SeededNoise {
    state: u64,
}

impl SeededNoise {
    pub fn new(seed: u64) -> SeededNoise {
        SeededNoise {
            state: seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state ^= self.state << 13;
        self.state ^= self.state >> 7;
        self.state ^= self.state << 17;
        self.state
    }
}

//! Good case for the `ambient-entropy` exemption: the bench harness is
//! the one library module allowed to read the wall clock.

pub fn time_ns<F: FnMut()>(mut f: F) -> u64 {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed().as_nanos() as u64
}

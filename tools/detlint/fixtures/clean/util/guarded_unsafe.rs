//! Good case for `safety-comment`: every unsafe site states its
//! aliasing/lifetime argument.

pub struct RawSlot(*mut f64);

// SAFETY: a RawSlot is only ever handed to one worker at a time, and the
// constructor guarantees the pointee outlives every send.
unsafe impl Send for RawSlot {}

pub fn read(slot: &RawSlot) -> f64 {
    // SAFETY: the pointer is valid and exclusively owned for this call.
    unsafe { *slot.0 }
}

pub fn write(slot: &mut RawSlot, v: f64) {
    unsafe { *slot.0 = v } // SAFETY: &mut receiver gives exclusive access
}

//! Good case for `float-ord`: floats are ordered through `total_cmp`,
//! which is a total order (NaN sorts deterministically).

pub fn median(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2]
}

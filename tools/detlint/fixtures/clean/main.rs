//! Good case for the `ambient-entropy` exemption: the CLI entry point
//! owns argv, the environment, and the wall clock.

fn main() {
    let started = std::time::Instant::now();
    let args: Vec<String> = std::env::args().collect();
    println!("{} args in {:?}", args.len(), started.elapsed());
}

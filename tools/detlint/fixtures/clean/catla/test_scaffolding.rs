//! Good case for the `#[cfg(test)]` exemption: test scaffolding may use
//! temp dirs and env reads without tripping `ambient-entropy`, because
//! nothing under `cfg(test)` ships in the production binary.

pub fn parse(line: &str) -> Option<(String, String)> {
    let (k, v) = line.split_once('=')?;
    Some((k.trim().to_string(), v.trim().to_string()))
}

#[cfg(test)]
mod tests {
    use super::parse;

    #[test]
    fn parses_and_uses_a_temp_dir() {
        let dir = std::env::temp_dir();
        assert!(!dir.as_os_str().is_empty());
        assert_eq!(parse("a = b").unwrap().0, "a");
    }
}

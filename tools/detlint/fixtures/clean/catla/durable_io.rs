//! Good cases for `raw-fs-write`: production writes routed through
//! `util::durable`, a justified escape hatch, and test scaffolding
//! (exempt — nothing under `cfg(test)` ships).

use std::path::Path;

pub fn persist(path: &Path, text: &str) -> std::io::Result<()> {
    crate::util::durable::atomic_write(path, text.as_bytes())
}

pub fn scratch(path: &Path) -> std::io::Result<()> {
    // detlint: allow(raw-fs-write) -- throwaway debug dump outside any recovery path
    std::fs::write(path, b"scratch")
}

#[cfg(test)]
mod tests {
    use super::persist;

    #[test]
    fn writes_fixtures_raw() {
        let p = std::env::temp_dir().join("detlint-fixture");
        std::fs::write(&p, "seed").unwrap();
        persist(&p, "replaced").unwrap();
        std::fs::remove_file(&p).unwrap();
    }
}

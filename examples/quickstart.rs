//! Quickstart — the paper's §II.B.2 five-step walkthrough as a library
//! client: create a task project from the template, run WordCount on the
//! (simulated) cluster, and read the downloaded metrics.
//!
//! Run: `cargo run --release --example quickstart`

use catla::catla::{create_template, History, Project, ProjectKind, TaskRunner};
use catla::hadoop::{Cluster, ClusterSpec, SimCluster};

fn main() -> Result<(), String> {
    // Step 1: prepare the project folder from the task template
    let dir = std::env::temp_dir().join("catla_quickstart_task_wordcount");
    let _ = std::fs::remove_dir_all(&dir);
    create_template(&dir, ProjectKind::Task, "wordcount", 10_240.0)?;
    println!("Step 1-2: project folder {} (edit HadoopEnv.txt for your cluster)", dir.display());

    // Step 3-4: load the project, connect the cluster, run the task tool
    let project = Project::load(&dir)?;
    let mut cluster = SimCluster::new(ClusterSpec::from_env(&project.env));
    println!("Step 3:   {}", cluster.describe());

    let mut runner = TaskRunner::new(&mut cluster);
    let out = runner.run(&project)?;
    println!(
        "Step 4:   job {} SUCCEEDED in {:.1}s ({} maps, {} reduces, {:.0}% node-local)",
        out.job_id,
        out.metrics.runtime_s,
        out.metrics.maps,
        out.metrics.reduces,
        out.metrics.data_local_fraction * 100.0
    );

    // Step 5: the analyzing results are in downloaded_results/
    println!("Step 5:   downloaded_results/ contents:");
    let mut names: Vec<String> = std::fs::read_dir(out.results_dir)
        .map_err(|e| e.to_string())?
        .filter_map(|e| e.ok().map(|e| e.file_name().to_string_lossy().into_owned()))
        .collect();
    names.sort();
    for n in names.iter().take(6) {
        println!("            {n}");
    }

    // and /history holds the CSV summary for visualization
    let history = History::open(&dir).map_err(|e| e.to_string())?;
    let jobs = history.load_jobs()?;
    println!(
        "history:  jobs.csv has {} row(s); columns: {}",
        jobs.rows.len(),
        jobs.header.join(", ")
    );
    Ok(())
}

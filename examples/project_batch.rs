//! Project Runner — run an organized group of jobs across all five
//! built-in workloads (the "project-based template" flow), then compare
//! their resource profiles from the downloaded metrics.
//!
//! Run: `cargo run --release --example project_batch`

use catla::catla::{create_template, Project, ProjectKind, ProjectRunner};
use catla::hadoop::{Cluster, ClusterSpec, SimCluster};

fn main() -> Result<(), String> {
    let dir = std::env::temp_dir().join("catla_project_batch");
    let _ = std::fs::remove_dir_all(&dir);
    create_template(&dir, ProjectKind::Project, "wordcount", 4096.0)?;

    // replace the template's jobs.list with a five-workload comparison,
    // each with a sensible non-default configuration override
    std::fs::write(
        dir.join("jobs.list"),
        "wc    wordcount 4096 conf.mapreduce.job.reduces=16\n\
         sort  terasort  4096 conf.mapreduce.job.reduces=32 conf.mapreduce.task.io.sort.mb=512\n\
         grep  grep      4096 conf.mapreduce.job.reduces=4\n\
         join  join      4096 conf.mapreduce.job.reduces=24\n\
         pr    pagerank  4096 conf.mapreduce.job.reduces=16 conf.mapreduce.map.output.compress=1\n",
    )
    .map_err(|e| e.to_string())?;

    let project = Project::load(&dir)?;
    let mut cluster = SimCluster::new(ClusterSpec::from_env(&project.env));
    println!("{}\n", cluster.describe());

    let out = ProjectRunner::new(&mut cluster).run(&project)?;

    println!(
        "{:<6} {:>10} {:>10} {:>10} {:>8} {:>10}",
        "job", "runtime_s", "map_s", "reduce_s", "maps", "shuffle_MB"
    );
    for (name, m) in &out.jobs {
        println!(
            "{:<6} {:>10.1} {:>10.1} {:>10.1} {:>8} {:>10.0}",
            name, m.runtime_s, m.map_phase_s, m.reduce_phase_s, m.maps, m.shuffle_mb
        );
    }
    println!(
        "\nall artifacts organized under {} (per-job subfolders + history/jobs.csv)",
        project.results_dir().display()
    );
    Ok(())
}

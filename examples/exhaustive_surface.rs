//! Exhaustive-search surface — regenerate the paper's Fig. 2: WordCount
//! running time over `mapreduce.job.reduces` × `mapreduce.task.io.sort.mb`,
//! rendered as a terminal heat map + CSV + gnuplot script.
//!
//! Run: `cargo run --release --example exhaustive_surface [out_dir]`

use catla::catla::visualize::{gnuplot_fig2, surface_heatmap};
use catla::config::params::{HadoopConfig, P_IO_SORT_MB, P_REDUCES};
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::{ClusterObjective, Driver, GridSearch, ParamSpace};
use catla::util::csv::Csv;
use catla::workloads::wordcount;

fn main() -> Result<(), String> {
    let out_dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "history".into()),
    );
    let workload = wordcount(10_240.0);
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let spec = TuningSpec::fig2();
    let space = ParamSpace::new(spec.clone(), HadoopConfig::default());
    println!(
        "exhaustive search over {} = {} cluster runs ...",
        spec.ranges
            .iter()
            .map(|r| r.grid().len().to_string())
            .collect::<Vec<_>>()
            .join(" x "),
        spec.grid_size()
    );

    let outcome = {
        // the whole grid is ONE ask-batch, evaluated across the pool
        let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
        Driver::new(usize::MAX).run(&mut GridSearch::new(), &space, &mut obj)?
    };

    // organize into the (reduces, sort.mb) matrix
    let reduces_axis = spec.ranges[0].grid();
    let sortmb_axis = spec.ranges[1].grid();
    let mut z = vec![vec![0.0f64; sortmb_axis.len()]; reduces_axis.len()];
    let mut csv = Csv::new(&["mapreduce.job.reduces", "mapreduce.task.io.sort.mb", "runtime_s"]);
    for rec in &outcome.records {
        let r = rec.config.get(P_REDUCES);
        let s = rec.config.get(P_IO_SORT_MB);
        let ri = reduces_axis.iter().position(|&v| v == r).unwrap();
        let si = sortmb_axis.iter().position(|&v| v == s).unwrap();
        z[ri][si] = rec.value;
        csv.push(&[r.to_string(), s.to_string(), format!("{:.3}", rec.value)]);
    }

    std::fs::create_dir_all(&out_dir).map_err(|e| e.to_string())?;
    let csv_path = out_dir.join("fig2_surface.csv");
    csv.save(&csv_path).map_err(|e| e.to_string())?;
    let gp_path = out_dir.join("fig2.gnuplot");
    std::fs::write(&gp_path, gnuplot_fig2("fig2_surface.csv", "fig2.png"))
        .map_err(|e| e.to_string())?;

    println!(
        "\n{}",
        surface_heatmap(
            "Fig. 2 — WordCount running time (simulated cluster)",
            "reduces",
            &reduces_axis,
            "io.sort.mb",
            &sortmb_axis,
            &z,
        )
    );
    println!(
        "best: {:.1}s at {}   worst: {:.1}s",
        outcome.best_value,
        outcome.best_config.summary(),
        outcome
            .records
            .iter()
            .map(|r| r.value)
            .fold(f64::MIN, f64::max)
    );
    println!("wrote {} and {}", csv_path.display(), gp_path.display());
    Ok(())
}

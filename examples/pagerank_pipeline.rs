//! PageRank pipeline — a multi-stage workflow DAG (prep → N rank
//! iterations → merge), tuned as a group: the shared configuration found
//! by the group tuner is applied to every stage and the end-to-end
//! makespan is compared against Hadoop defaults.
//!
//! Run: `cargo run --release --example pagerank_pipeline [iterations]`

use catla::catla::workflow::{parse_workflow_line, run_workflow, WorkflowJob};
use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::{Bobyqa, ClusterObjective, Driver, ParamSpace};
use catla::workloads::pagerank_iteration;

fn pipeline(iters: usize, cfg_args: &str) -> Vec<WorkflowJob> {
    let mut lines = vec![format!("prep grep 4096 {cfg_args}")];
    for i in 1..=iters {
        let dep = if i == 1 { "prep".to_string() } else { format!("rank{}", i - 1) };
        lines.push(format!("rank{i} pagerank 2048 {cfg_args} after={dep}"));
    }
    lines.push(format!(
        "merge join 4096 {cfg_args} after=rank{iters}"
    ));
    lines
        .iter()
        .map(|l| parse_workflow_line(l).expect("valid line"))
        .collect()
}

fn main() -> Result<(), String> {
    let iters: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);

    // tune a shared config on the dominant stage (one rank iteration)
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let wl = pagerank_iteration(2048.0);
    let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    let outcome = {
        let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
        Driver::new(40)
            .run(&mut Bobyqa::default(), &space, &mut obj)
            .expect("tuning run")
    };
    println!(
        "tuned shared config in {} evals: {}",
        outcome.evals(),
        outcome.best_config.summary()
    );
    let cfg_args = TuningSpec::fig3()
        .ranges
        .iter()
        .map(|r| format!("conf.{}={}", r.name(), outcome.best_config.get(r.index)))
        .collect::<Vec<_>>()
        .join(" ");

    // run the DAG under defaults vs tuned
    let default_wf = pipeline(iters, "");
    let tuned_wf = pipeline(iters, &cfg_args);
    let mut c1 = SimCluster::new(ClusterSpec::default());
    let mut c2 = SimCluster::new(ClusterSpec::default());
    let before = run_workflow(&mut c1, &default_wf)?;
    let after = run_workflow(&mut c2, &tuned_wf)?;

    println!("\n{:<10} {:>12} {:>12}", "stage", "default_s", "tuned_s");
    for (a, b) in before.stages.iter().zip(&after.stages) {
        println!("{:<10} {:>12.1} {:>12.1}", a.name, a.runtime_s, b.runtime_s);
    }
    println!(
        "\npipeline makespan: default {:.1}s -> tuned {:.1}s ({:.1}% faster, {} stages)",
        before.makespan_s,
        after.makespan_s,
        (1.0 - after.makespan_s / before.makespan_s) * 100.0,
        before.stages.len()
    );
    if after.makespan_s >= before.makespan_s {
        return Err("tuned pipeline not faster than defaults".into());
    }
    Ok(())
}

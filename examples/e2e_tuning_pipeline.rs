//! END-TO-END DRIVER — exercises every layer of the stack on a real
//! small workload and reports the paper's headline metric.
//!
//! Pipeline: synthetic 1 GiB WordCount corpus → 16-node simulated Hadoop
//! cluster (L3 substrate) → Catla Optimizer Runner with BOBYQA seeded by
//! surrogate prescreening through the AOT JAX/Pallas cost model executed
//! via XLA PJRT (L1+L2 → runtime) → tuned vs default configuration,
//! cluster evaluations vs exhaustive search.
//!
//! Run: `make artifacts && cargo run --release --example e2e_tuning_pipeline`
//! Results recorded in EXPERIMENTS.md §End-to-end.

use catla::catla::visualize::line_chart;
use catla::catla::{create_template, History, Project, ProjectKind};
use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::{Cluster, ClusterSpec, JobSubmission, SimCluster};
use catla::optim::surrogate::Prescreen;
use catla::optim::{ClusterObjective, ParamSpace};
use catla::runtime::{CostModelExec, Runtime};
use catla::workloads::wordcount;

fn main() -> Result<(), String> {
    println!("=== Catla end-to-end tuning pipeline ===\n");

    // ---- 1. workload + project folder ----------------------------------
    let input_mb = 1024.0; // "real small workload": 1 GiB corpus profile
    let workload = wordcount(input_mb);
    let dir = std::env::temp_dir().join("catla_e2e_pipeline");
    let _ = std::fs::remove_dir_all(&dir);
    create_template(&dir, ProjectKind::Tuning, "wordcount", input_mb)?;
    let project = Project::load(&dir)?;
    println!("[1] tuning project at {}", dir.display());

    // ---- 2. cluster substrate ------------------------------------------
    let cluster_spec = ClusterSpec::from_env(&project.env);
    let mut cluster = SimCluster::new(cluster_spec.clone());
    println!("[2] {}", cluster.describe());

    // ---- 3. L1+L2 via PJRT: surrogate prescreening ----------------------
    let rt = Runtime::open_default()?;
    let mut scorer = CostModelExec::load(&rt, &workload, &cluster_spec)?;
    println!(
        "[3] batched cost model ready ({} backend, artifacts dir {})",
        rt.backend(),
        rt.artifacts_dir.display()
    );

    let spec = TuningSpec::fig3();
    let space = ParamSpace::new(spec.clone(), project.base_config()?);
    let budget = 40;

    // ---- 4. tuning: prescreened BOBYQA vs raw BOBYQA vs exhaustive ------
    let mut prescreen = Prescreen::new(&mut scorer);
    prescreen.n_candidates = 4096;
    let outcome = {
        let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
        prescreen.run_bobyqa(&space, &mut obj, budget)?
    };
    println!(
        "[4] {} finished: {} cluster evaluations, best {:.1}s",
        outcome.optimizer,
        outcome.evals(),
        outcome.best_value
    );

    // ---- 5. headline metrics --------------------------------------------
    let avg = |cluster: &mut SimCluster, cfg: &HadoopConfig, n: u64| -> f64 {
        (0..n)
            .map(|_| {
                cluster
                    .run_job(&JobSubmission {
                        name: "verify".into(),
                        workload: workload.clone(),
                        config: cfg.clone(),
                    })
                    .runtime_s
            })
            .sum::<f64>()
            / n as f64
    };
    let default_rt = avg(&mut cluster, &HadoopConfig::default(), 15);
    let tuned_rt = avg(&mut cluster, &outcome.best_config, 15);
    let grid_size = TuningSpec::fig3()
        .ranges
        .iter()
        .map(|r| r.grid().len())
        .product::<usize>();

    println!("\n=== headline results (paper's motivation) ===");
    println!("default configuration : {default_rt:.1}s (mean of 15 runs)");
    println!(
        "tuned configuration   : {tuned_rt:.1}s  ->  {:.2}x speedup / {:.0}% runtime reduction",
        default_rt / tuned_rt,
        (1.0 - tuned_rt / default_rt) * 100.0
    );
    println!(
        "cluster evaluations   : {} (vs {} for exhaustive search over the same 4-D space: {:.0}x fewer)",
        outcome.evals(),
        grid_size,
        grid_size as f64 / outcome.evals() as f64
    );
    println!("best config           : {}", outcome.best_config.summary());
    println!(
        "surrogate batches     : {} batched executions for {} scored candidates",
        scorer.calls, 4096
    );

    // ---- 6. logs + convergence chart (CatlaUI view) ----------------------
    let history = History::open(&dir).map_err(|e| e.to_string())?;
    history.write_tuning_log(&spec, &outcome)?;
    history.append_summary(&spec, &outcome)?;
    println!("\nlogs: {}", history.dir.display());
    println!(
        "\n{}",
        line_chart("best-so-far (convergence)", &outcome.convergence(), 64, 12)
    );

    if tuned_rt >= default_rt {
        return Err("pipeline completed but tuning failed to beat the default".into());
    }
    println!("e2e pipeline OK");
    Ok(())
}

//! Trace replay — "tune once, run the pipeline faster": generate a
//! day-long mixed-workload arrival trace, tune one shared configuration
//! on a representative job, then replay the whole trace under default vs
//! tuned configs and compare makespan / waits / utilization.
//!
//! Run: `cargo run --release --example trace_replay [n_jobs]`

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::hadoop::trace::{replay, TraceGen};
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::{Bobyqa, ClusterObjective, Driver, ParamSpace};
use catla::workloads::wordcount;

fn main() {
    let n_jobs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    // a loaded cluster: jobs arrive faster than the default config drains
    let gen = TraceGen {
        mean_interarrival_s: 25.0,
        ..TraceGen::default()
    };
    let trace = gen.generate(n_jobs, 42);
    let cl = ClusterSpec::default();
    println!(
        "trace: {n_jobs} jobs over {:.1} h (mixed: wc/grep/terasort/join/pagerank)",
        trace.last().unwrap().arrival_s / 3600.0
    );

    // tune one shared config on the dominant workload
    let mut cluster = SimCluster::new(cl.clone());
    let wl = wordcount(2048.0);
    let space = ParamSpace::new(TuningSpec::fig3(), HadoopConfig::default());
    let outcome = {
        let mut obj = ClusterObjective::new(&mut cluster, &wl, 1);
        Driver::new(40)
            .run(&mut Bobyqa::default(), &space, &mut obj)
            .expect("tuning run")
    };
    println!(
        "tuned on representative wordcount in {} evals -> {}",
        outcome.evals(),
        outcome.best_config.summary()
    );

    let before = replay(&cl, &trace, &HadoopConfig::default(), 7);
    let after = replay(&cl, &trace, &outcome.best_config, 7);

    println!("\n{:<22} {:>12} {:>12}", "metric", "default", "tuned");
    for (name, a, b) in [
        ("makespan (h)", before.makespan_s / 3600.0, after.makespan_s / 3600.0),
        ("mean job runtime (s)", before.mean_runtime_s, after.mean_runtime_s),
        ("mean queue wait (s)", before.mean_wait_s, after.mean_wait_s),
        ("p95 queue wait (s)", before.p95_wait_s, after.p95_wait_s),
        ("utilization", before.utilization, after.utilization),
    ] {
        println!("{name:<22} {a:>12.2} {b:>12.2}");
    }
    println!(
        "\nmakespan reduction: {:.1}%   wait reduction: {:.1}%",
        (1.0 - after.makespan_s / before.makespan_s) * 100.0,
        (1.0 - after.mean_wait_s / before.mean_wait_s.max(1e-9)) * 100.0
    );
}

//! Tune WordCount with BOBYQA — the paper's Fig. 3 scenario as a library
//! client: 4 Hadoop parameters, 60 noisy cluster evaluations, convergence
//! chart on the terminal.
//!
//! Run: `cargo run --release --example tune_wordcount [budget]`

use catla::config::params::HadoopConfig;
use catla::config::spec::TuningSpec;
use catla::catla::visualize::line_chart;
use catla::hadoop::{ClusterSpec, SimCluster};
use catla::optim::core::BatchObjective;
use catla::optim::{Bobyqa, ClusterObjective, Driver, ParamSpace};
use catla::workloads::wordcount;

fn main() {
    let budget: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    let workload = wordcount(10_240.0);
    let mut cluster = SimCluster::new(ClusterSpec::default());
    let spec = TuningSpec::fig3();
    let space = ParamSpace::new(spec.clone(), HadoopConfig::default());

    println!("tuning {} over {} parameters, budget {budget} evaluations:", workload.name, spec.dims());
    for r in &spec.ranges {
        println!("  {:<48} [{}, {}]", r.name(), r.lo, r.hi);
    }

    // default-config baseline (what a user who never tunes gets)
    let mut obj = ClusterObjective::new(&mut cluster, &workload, 1);
    let default_runtime = obj.eval_batch(&[HadoopConfig::default()]).unwrap()[0];

    let outcome = Driver::new(budget)
        .run(&mut Bobyqa::default(), &space, &mut obj)
        .expect("tuning run");
    drop(obj);

    println!("\nbest configuration found ({} evals):", outcome.evals());
    for r in &spec.ranges {
        println!(
            "  {:<48} {}",
            r.name(),
            outcome.best_config.get(r.index)
        );
    }
    println!(
        "\ndefault config: {default_runtime:.1}s   tuned: {:.1}s   speedup: {:.2}x",
        outcome.best_value,
        default_runtime / outcome.best_value
    );

    println!("\n{}", line_chart("running time per iteration (raw)", &outcome.raw_series(), 64, 14));
    println!("{}", line_chart("best-so-far (convergence)", &outcome.convergence(), 64, 14));
}
